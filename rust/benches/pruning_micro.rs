//! Micro-benchmarks of the host-side pruning criteria: magnitude vs Wanda vs
//! SparseGPT on one realistic linear layer, across sizes and patterns.
//!
//! SparseGPT's O(in²·out / blocksize) OBS sweep dominates — this bench is
//! the profile driver for the §Perf pruning work.

mod common;

use perp::pruning::{magnitude, sparsegpt, wanda, Pattern};
use perp::tensor::{linalg, Tensor};
use perp::util::bench::{fmt_duration, Bench, Table};
use perp::util::rng::Rng;
use std::collections::BTreeMap;

fn main() {
    let bench = Bench::quick();

    // the calibration Gram build (XᵀX) is the pruning pre-pass hot spot —
    // show the serial baseline vs the rayon kernel feeding it
    let mut gram_t = Table::new(
        "calibration Gram XᵀX: serial vs rayon",
        &["X shape", "serial", "rayon", "speedup"],
    );
    let mut grng = Rng::new(7);
    for (rows, inp) in [(512usize, 256usize), (1024, 512), (2048, 512)] {
        let x = Tensor::randn(&[rows, inp], 1.0, &mut grng);
        let xt = x.transpose2();
        let s = bench.run(|| {
            std::hint::black_box(linalg::matmul_serial(&xt, &x));
        });
        let p = bench.run(|| {
            std::hint::black_box(linalg::matmul_tn(&x, &x));
        });
        gram_t.row(vec![
            format!("{rows}x{inp}"),
            perp::util::bench::fmt_duration(s.mean),
            perp::util::bench::fmt_duration(p.mean),
            format!("{:.2}x", s.mean_secs() / p.mean_secs()),
        ]);
    }
    gram_t.print();

    let mut table = Table::new(
        "pruning criteria micro-bench (one linear layer)",
        &["layer (out x in)", "pattern", "magnitude", "wanda", "sparsegpt"],
    );
    let mut rng = Rng::new(42);
    for (out, inp) in [(64usize, 64usize), (128, 128), (256, 256), (512, 128)] {
        let w = Tensor::randn(&[out, inp], 0.05, &mut rng);
        let x = Tensor::randn(&[256, inp], 1.0, &mut rng);
        let gram = linalg::matmul_tn(&x, &x);
        for pattern in [Pattern::Unstructured(0.5), Pattern::SemiStructured { n: 2, m: 4 }] {
            let mut weights = BTreeMap::new();
            weights.insert("w".to_string(), &w);
            let t_mag = bench.run(|| {
                std::hint::black_box(magnitude::uniform(&weights, pattern));
            });
            let t_wanda = bench.run(|| {
                std::hint::black_box(wanda::mask(&w, &gram, pattern));
            });
            let t_sgpt = bench.run(|| {
                std::hint::black_box(sparsegpt::prune_layer(&w, &gram, pattern, 64, 0.01));
            });
            table.row(vec![
                format!("{out}x{inp}"),
                pattern.label(),
                fmt_duration(t_mag.mean),
                fmt_duration(t_wanda.mean),
                fmt_duration(t_sgpt.mean),
            ]);
        }
    }
    table.print();
    std::fs::create_dir_all("results").ok();
    gram_t.append_to(std::path::Path::new("results/bench_tables.md")).ok();
    table.append_to(std::path::Path::new("results/bench_tables.md")).ok();
}
