"""MaskLoRA fused forward/backward Pallas kernels (PERP §3.2).

The PERP hot spot: ``y = x @ (W*M + M ⊙ (s·B@A))^T``.  The naive PyTorch
implementation in the paper materialises ``B@A`` at full (out, in) size, masks
it, adds it to W and runs a second GEMM — this is their "MaskLoRA (standard)"
row in Table 4 (3,000 tps vs 5,300 for LoRA).  Their "optimized" variant fuses
the adapter construction into the forward (4,700 tps).

This kernel is the TPU-shaped expression of that optimization: per (bm, bk)
weight tile we compute ``B_tile @ A_tile`` (an (bm, r) x (r, bk) MXU matmul,
r << bm,bk), apply the mask and the add entirely in VMEM, and feed the fused
tile straight into the main (bn, bk) x (bk, bm) contraction.  ``B@A`` never
exists at full size in HBM and the mask is read exactly once per tile.

The backward pass reuses the same fused-tile construction for
``dx = g @ Z`` and computes the adapter gradients through the masked
down-projection ``dZm = M ⊙ (g^T @ x)``:

    dA = s * B^T @ dZm        dB = s * dZm @ A^T
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MatmulBlocks, cdiv, scratch
from .matmul import mm_nt, mm_nn


def _fused_tile(w, m, a, b, scale):
    """Z-tile = W*M + M ⊙ (s·B@A) computed in registers/VMEM."""
    ba = jnp.dot(b, a, preferred_element_type=jnp.float32)
    return m * (w + scale * ba.astype(w.dtype))


def _fwd_kernel(x_ref, w_ref, m_ref, a_ref, b_ref, o_ref, acc_ref, *, nk, scale):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _fused_tile(w_ref[...], m_ref[...], a_ref[...], b_ref[...], scale)
    acc_ref[...] += jnp.dot(x_ref[...], z.T, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def masked_lora_matmul_fwd_kernel(x, w, mask, a, b, scale: float):
    """Raw fused forward: x:(n,k), w/mask:(m,k), a:(r,k), b:(m,r) -> (n,m)."""
    n, k = x.shape
    m, k2 = w.shape
    r, k3 = a.shape
    m2, r2 = b.shape
    assert k == k2 == k3 and m == m2 and r == r2, (x.shape, w.shape, a.shape, b.shape)
    blk = MatmulBlocks.choose(n, m, k)
    nk = cdiv(k, blk.bk)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, nk=nk, scale=scale),
        grid=(cdiv(n, blk.bn), cdiv(m, blk.bm), nk),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),  # x
            pl.BlockSpec((blk.bm, blk.bk), lambda i, j, l: (j, l)),  # w
            pl.BlockSpec((blk.bm, blk.bk), lambda i, j, l: (j, l)),  # mask
            pl.BlockSpec((r, blk.bk), lambda i, j, l: (0, l)),       # a
            pl.BlockSpec((blk.bm, r), lambda i, j, l: (j, 0)),       # b
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(x, w, mask, a, b)


def _bwd_dx_kernel(g_ref, w_ref, m_ref, a_ref, b_ref, o_ref, acc_ref, *, nm, scale):
    # dx:(n,k) = g:(n,m) @ Z:(m,k); grid (n-blocks, k-blocks, m-blocks).
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _fused_tile(w_ref[...], m_ref[...], a_ref[...], b_ref[...], scale)
    acc_ref[...] += jnp.dot(g_ref[...], z, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nm - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def masked_lora_matmul_bwd_dx_kernel(g, w, mask, a, b, scale: float):
    """dx = g @ Z with the Z tiles fused exactly like the forward."""
    n, m = g.shape
    m2, k = w.shape
    r = a.shape[0]
    assert m == m2
    blk = MatmulBlocks.choose(n, k, m)  # contraction dim is m here
    nm = cdiv(m, blk.bk)
    return pl.pallas_call(
        functools.partial(_bwd_dx_kernel, nm=nm, scale=scale),
        grid=(cdiv(n, blk.bn), cdiv(k, blk.bm), nm),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),  # g
            pl.BlockSpec((blk.bk, blk.bm), lambda i, j, l: (l, j)),  # w
            pl.BlockSpec((blk.bk, blk.bm), lambda i, j, l: (l, j)),  # mask
            pl.BlockSpec((r, blk.bm), lambda i, j, l: (0, j)),       # a
            pl.BlockSpec((blk.bk, r), lambda i, j, l: (l, 0)),       # b
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), g.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(g, w, mask, a, b)


# ---------------------------------------------------------------------------
# Differentiable wrapper.  Trainables are (a, b); w and mask are frozen in
# MaskLoRA retraining, but we still emit dw for the layer-wise full-FT
# reconstruction baseline (Table 19) where W itself is optimised.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def masked_lora_matmul(x, w, mask, a, b, scale):
    """y = x @ (M ⊙ (W + s·B@A))^T — fused pallas fwd + bwd."""
    return masked_lora_matmul_fwd_kernel(x, w, mask, a, b, scale)


def _mlm_fwd(x, w, mask, a, b, scale):
    return masked_lora_matmul_fwd_kernel(x, w, mask, a, b, scale), (x, w, mask, a, b)


def _mlm_bwd(scale, res, g):
    x, w, mask, a, b = res
    dx = masked_lora_matmul_bwd_dx_kernel(g, w, mask, a, b, scale)
    # dZ = g^T @ x, masked.  The full-size (m, k) gradient exists only in the
    # backward pass (same as the paper's autograd behaviour).
    dzm = mm_nt(g.T, x.T) * mask
    da = scale * mm_nn(b.T, dzm)
    db = scale * mm_nt(dzm, a)
    dw = dzm  # ∂y/∂W = M ⊙ (g^T x); zero where pruned.
    return dx, dw, None, da, db


masked_lora_matmul.defvjp(_mlm_fwd, _mlm_bwd)
