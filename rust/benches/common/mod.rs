//! Shared bench scaffolding: every paper-table bench builds an ExpContext
//! against the cached quick-profile checkpoints and appends its markdown
//! table to `results/bench_tables.md`.

use std::path::PathBuf;

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::{self, ExpContext};
use perp::runtime::{default_artifacts_dir, Runtime};

pub fn bench_model() -> String {
    std::env::var("PERP_BENCH_MODEL").unwrap_or_else(|_| "gpt-nano".to_string())
}

pub fn bench_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick(&bench_model());
    cfg.pretrain_steps = std::env::var("PERP_BENCH_PRETRAIN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000);
    cfg.retrain_steps = 60;
    cfg.recon_steps = 20;
    cfg.items_per_task = 20;
    cfg
}

pub fn run_experiment(exp: &str) {
    let rt = Runtime::new(&default_artifacts_dir()).expect("make artifacts first");
    let ctx = ExpContext::new(&rt, bench_cfg(), PathBuf::from("results/cache"));
    let t0 = std::time::Instant::now();
    let tables = sweep::run(&ctx, exp).expect("sweep failed");
    let out = PathBuf::from("results/bench_tables.md");
    std::fs::create_dir_all("results").ok();
    for t in &tables {
        t.print();
        t.append_to(&out).ok();
    }
    println!(
        "bench[{exp}] ({}): {:.1}s, {} device executions",
        bench_model(),
        t0.elapsed().as_secs_f64(),
        rt.exec_count.borrow()
    );
}
