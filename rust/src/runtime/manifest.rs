//! Model manifest: the contract between graph producers and the rust
//! coordinator.
//!
//! Two producers exist:
//!
//! * [`Manifest::builtin`] — the hermetic default.  A rust port of
//!   `python/compile/model.py`'s spec builders (`param_specs`, `tap_of`,
//!   `adapter_specs`, `trainable_names`) plus the executable I/O tables
//!   `aot.py` would record.  This is what the [`NativeBackend`] executes
//!   against; no artifacts directory required.
//! * [`Manifest::load`] — `manifest.json` written by `aot.py` alongside the
//!   AOT-lowered HLO-text artifacts, consumed by the PJRT backend.
//!
//! For every executable the manifest records the exact input/output tensor
//! names, shapes and dtypes in call order.  Everything the rust side knows
//! about a model (parameter inventory, groups, prunable set, adapter shapes,
//! trainable sets per mode) comes from here — there is no second source of
//! truth.
//!
//! [`NativeBackend`]: crate::runtime::NativeBackend

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?} in manifest"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req("name").as_str().context("io name")?.to_string(),
            shape: j
                .req("shape")
                .as_arr()
                .context("io shape")?
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect(),
            dtype: DType::parse(j.req("dtype").as_str().context("io dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub group: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static model configuration mirrored from python's ModelConfig.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub d_ff: usize,
    pub use_bias: bool,
    pub norm: String,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub lora_scale: f64,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub calib_rows: usize,
    /// Concurrent KV-cache decode slots of the serving executables
    /// (`prefill` / `decode_step`) — the lock-step batch width of the
    /// dynamic request batcher.
    pub serve_slots: usize,
    /// Token width of the speculative `verify_step` executable: the target
    /// model scores up to `spec_width` positions per stream in one pass, so
    /// the largest usable draft length is `spec_width - 1` (one slot goes
    /// to the already-committed input token).
    pub spec_width: usize,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub cfg: ModelCfg,
    pub params: Vec<ParamSpec>,
    pub prunable: Vec<String>,
    /// prunable linear -> capture tap that carries its input (q/k/v share)
    pub taps: BTreeMap<String, String>,
    /// adapter tensors: name (e.g. "h0_attn_q_w::A") -> shape
    pub adapters: Vec<(String, Vec<usize>)>,
    /// retraining mode -> model-parameter names trained under it
    pub trainable: BTreeMap<String, Vec<String>>,
    pub executables: BTreeMap<String, ExecSpec>,
}

impl ModelManifest {
    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn param_shape(&self, name: &str) -> &[usize] {
        &self
            .param(name)
            .unwrap_or_else(|| panic!("unknown param {name:?}"))
            .shape
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .with_context(|| format!("executable {name:?} not in manifest (model {})", self.cfg.name))
    }

    pub fn adapter_shape(&self, name: &str) -> &[usize] {
        &self
            .adapters
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown adapter {name:?}"))
            .1
    }

    /// Total trainable parameter count for a retraining mode (incl adapters
    /// for LoRA modes) — the "% trainable" column of the paper's tables.
    pub fn trainable_count(&self, mode: &str) -> usize {
        let base: usize = self
            .trainable
            .get(mode)
            .map(|names| {
                names
                    .iter()
                    .map(|n| self.param(n).map(|p| p.numel()).unwrap_or(0))
                    .sum()
            })
            .unwrap_or(0);
        let adapters: usize = if is_lora_mode(mode) {
            self.adapters.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
        } else {
            0
        };
        base + adapters
    }
}

pub fn is_lora_mode(mode: &str) -> bool {
    matches!(mode, "lora" | "masklora" | "masklora_std" | "scalelora")
}

/// Canonical adapter-name split: `"h0_attn_q_w::A"` -> `("h0_attn_q_w", "a")`
/// — the single place the `<linear>::A/B` <-> `a::<linear>`/`b::<linear>`
/// naming convention is decoded.
pub fn split_adapter_name(name: &str) -> (&str, &'static str) {
    if let Some(lin) = name.strip_suffix("::A") {
        (lin, "a")
    } else if let Some(lin) = name.strip_suffix("::B") {
        (lin, "b")
    } else {
        panic!("not an adapter name: {name:?}")
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

// ---------------------------------------------------------------------------
// Builtin manifest: the hermetic port of model.py + aot.py's spec tables.
// ---------------------------------------------------------------------------

impl ModelCfg {
    pub fn d_head(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// The repro fleet (mirrors python's CONFIGS map).
    pub fn builtin(name: &str) -> Option<ModelCfg> {
        let base = |name: &str, vocab, d_model, n_layers, n_heads, seq_len, lora_rank| ModelCfg {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            seq_len,
            d_ff: 4 * d_model,
            use_bias: true,
            norm: "layernorm".to_string(),
            lora_rank,
            lora_alpha: 32.0,
            lora_scale: 32.0 / lora_rank as f64,
            train_batch: 8,
            eval_batch: 8,
            calib_rows: 512,
            serve_slots: 8,
            spec_width: 8,
        };
        Some(match name {
            "gpt-nano" => ModelCfg {
                train_batch: 4,
                eval_batch: 4,
                calib_rows: 128,
                ..base("gpt-nano", 128, 32, 2, 2, 32, 4)
            },
            "gpt-tiny" => ModelCfg { calib_rows: 256, ..base("gpt-tiny", 256, 64, 2, 2, 64, 8) },
            "gpt-small" => base("gpt-small", 512, 128, 4, 4, 128, 16),
            "gpt-medium" => base("gpt-medium", 1024, 256, 6, 8, 128, 16),
            "llama-tiny" => ModelCfg {
                use_bias: false,
                norm: "rmsnorm".to_string(),
                ..base("llama-tiny", 512, 128, 4, 4, 128, 16)
            },
            "gpt-e2e" => base("gpt-e2e", 2048, 384, 6, 8, 128, 16),
            _ => return None,
        })
    }

    pub const BUILTIN_NAMES: [&'static str; 6] = [
        "gpt-nano", "gpt-tiny", "gpt-small", "gpt-medium", "llama-tiny", "gpt-e2e",
    ];
}

/// (name, shape, group) for every parameter, in canonical order — the exact
/// port of model.py's `param_specs`.
fn builtin_param_specs(cfg: &ModelCfg) -> Vec<ParamSpec> {
    let (d, ff) = (cfg.d_model, cfg.d_ff);
    let layernorm = cfg.norm == "layernorm";
    let mut specs = vec![
        ParamSpec { name: "embed_tokens".into(), shape: vec![cfg.vocab, d], group: "embed".into() },
        ParamSpec { name: "embed_pos".into(), shape: vec![cfg.seq_len, d], group: "embed".into() },
    ];
    let mut push = |specs: &mut Vec<ParamSpec>, name: String, shape: Vec<usize>, group: &str| {
        specs.push(ParamSpec { name, shape, group: group.to_string() });
    };
    for i in 0..cfg.n_layers {
        let p = format!("h{i}_");
        push(&mut specs, format!("{p}ln1_scale"), vec![d], "ln");
        if layernorm {
            push(&mut specs, format!("{p}ln1_bias"), vec![d], "ln");
        }
        for lin in ["attn_q", "attn_k", "attn_v", "attn_o"] {
            push(&mut specs, format!("{p}{lin}_w"), vec![d, d], "weight");
            if cfg.use_bias {
                push(&mut specs, format!("{p}{lin}_b"), vec![d], "bias");
            }
        }
        push(&mut specs, format!("{p}ln2_scale"), vec![d], "ln");
        if layernorm {
            push(&mut specs, format!("{p}ln2_bias"), vec![d], "ln");
        }
        push(&mut specs, format!("{p}mlp_fc_w"), vec![ff, d], "weight");
        if cfg.use_bias {
            push(&mut specs, format!("{p}mlp_fc_b"), vec![ff], "bias");
        }
        push(&mut specs, format!("{p}mlp_proj_w"), vec![d, ff], "weight");
        if cfg.use_bias {
            push(&mut specs, format!("{p}mlp_proj_b"), vec![d], "bias");
        }
    }
    push(&mut specs, "final_ln_scale".into(), vec![d], "ln");
    if layernorm {
        push(&mut specs, "final_ln_bias".into(), vec![d], "ln");
    }
    push(&mut specs, "head_w".into(), vec![cfg.vocab, d], "head");
    specs
}

/// Map a prunable linear to the capture tap carrying its input (q/k/v share).
pub fn tap_of(name: &str) -> String {
    name.replace("attn_k", "attn_q").replace("attn_v", "attn_q")
}

/// Distinct capture points, in forward order (model.py `tap_names`).
pub fn builtin_tap_names(cfg: &ModelCfg) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..cfg.n_layers {
        for lin in ["attn_q_w", "attn_o_w", "mlp_fc_w", "mlp_proj_w"] {
            out.push(format!("h{i}_{lin}"));
        }
    }
    out
}

/// Model parameters (not adapters) trained under `mode` (model.py
/// `trainable_names`).
fn builtin_trainable(params: &[ParamSpec], mode: &str) -> Vec<String> {
    let pred: fn(&str) -> bool = match mode {
        "full" => |_| true,
        "biases" => |g| g == "bias",
        "ln" => |g| g == "ln",
        "biases_ln" => |g| g == "bias" || g == "ln",
        "head" => |g| g == "head",
        "embed" => |g| g == "embed",
        m if is_lora_mode(m) => |g| g == "bias" || g == "ln",
        other => panic!("unknown retraining mode {other:?}"),
    };
    params.iter().filter(|p| pred(&p.group)).map(|p| p.name.clone()).collect()
}

const ALL_MODES: [&str; 10] = [
    "full", "biases", "ln", "biases_ln", "head", "embed",
    "lora", "masklora", "masklora_std", "scalelora",
];

fn io(name: impl Into<String>, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.into(), shape: shape.to_vec(), dtype: DType::F32 }
}

fn io_i32(name: impl Into<String>, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.into(), shape: shape.to_vec(), dtype: DType::I32 }
}

impl ModelManifest {
    /// Build the full hermetic manifest entry for one config — parameter
    /// inventory plus the executable I/O tables aot.py would have recorded.
    pub fn builtin(cfg: ModelCfg) -> ModelManifest {
        let params = builtin_param_specs(&cfg);
        let shapes: BTreeMap<&str, &[usize]> =
            params.iter().map(|p| (p.name.as_str(), &p.shape[..])).collect();
        let prunable: Vec<String> = params
            .iter()
            .filter(|p| p.group == "weight")
            .map(|p| p.name.clone())
            .collect();
        let taps: BTreeMap<String, String> =
            prunable.iter().map(|n| (n.clone(), tap_of(n))).collect();
        let mut adapters: Vec<(String, Vec<usize>)> = Vec::new();
        for n in &prunable {
            let s = shapes[n.as_str()];
            adapters.push((format!("{n}::A"), vec![cfg.lora_rank, s[1]]));
            adapters.push((format!("{n}::B"), vec![s[0], cfg.lora_rank]));
        }
        let trainable: BTreeMap<String, Vec<String>> = ALL_MODES
            .iter()
            .map(|m| (m.to_string(), builtin_trainable(&params, m)))
            .collect();

        // ---- executable I/O tables ------------------------------------
        let param_inputs: Vec<IoSpec> =
            params.iter().map(|p| io(format!("p::{}", p.name), &p.shape)).collect();
        let mask_inputs: Vec<IoSpec> =
            prunable.iter().map(|n| io(format!("m::{n}"), shapes[n.as_str()])).collect();
        let adapter_inputs: Vec<IoSpec> = adapters
            .iter()
            .map(|(n, s)| {
                let (lin, tag) = split_adapter_name(n);
                io(format!("{tag}::{lin}"), s)
            })
            .collect();
        let leaf_shape = |n: &str| -> Vec<usize> {
            adapters
                .iter()
                .find(|(an, _)| an == n)
                .map(|(_, s)| s.clone())
                .unwrap_or_else(|| shapes[n].to_vec())
        };
        let tok_eval = io_i32("tokens", &[cfg.eval_batch, cfg.seq_len]);
        let tok_train = io_i32("tokens", &[cfg.train_batch, cfg.seq_len]);
        let scalar_ins = [io("step", &[]), io("lr", &[])];

        let mut executables = BTreeMap::new();
        let mut add = |name: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| {
            executables.insert(
                name.to_string(),
                ExecSpec { name: name.to_string(), file: String::new(), inputs, outputs },
            );
        };

        let base: Vec<IoSpec> =
            param_inputs.iter().chain(&mask_inputs).cloned().collect();
        let base_lora: Vec<IoSpec> =
            base.iter().chain(&adapter_inputs).cloned().collect();

        add(
            "eval_loss",
            base.iter().cloned().chain([tok_eval.clone()]).collect(),
            vec![io("loss_sum", &[]), io("count", &[])],
        );
        add(
            "score",
            base.iter()
                .cloned()
                .chain([tok_eval.clone(), io("tmask", &[cfg.eval_batch, cfg.seq_len])])
                .collect(),
            vec![io("scores", &[cfg.eval_batch]), io("counts", &[cfg.eval_batch])],
        );
        add(
            "eval_loss_lora",
            base_lora.iter().cloned().chain([tok_eval.clone()]).collect(),
            vec![io("loss_sum", &[]), io("count", &[])],
        );
        add(
            "score_lora",
            base_lora
                .iter()
                .cloned()
                .chain([tok_eval.clone(), io("tmask", &[cfg.eval_batch, cfg.seq_len])])
                .collect(),
            vec![io("scores", &[cfg.eval_batch]), io("counts", &[cfg.eval_batch])],
        );

        for mode in ALL_MODES {
            let lora = is_lora_mode(mode);
            let mut leaves = trainable[mode].clone();
            if lora {
                leaves.extend(adapters.iter().map(|(n, _)| n.clone()));
            }
            let mut inputs = if lora { base_lora.clone() } else { base.clone() };
            inputs.extend(leaves.iter().map(|n| io(format!("om::{n}"), &leaf_shape(n))));
            inputs.extend(leaves.iter().map(|n| io(format!("ov::{n}"), &leaf_shape(n))));
            inputs.push(tok_train.clone());
            inputs.extend(scalar_ins.iter().cloned());
            let mut outputs: Vec<IoSpec> =
                leaves.iter().map(|n| io(format!("o::{n}"), &leaf_shape(n))).collect();
            outputs.extend(leaves.iter().map(|n| io(format!("om::{n}"), &leaf_shape(n))));
            outputs.extend(leaves.iter().map(|n| io(format!("ov::{n}"), &leaf_shape(n))));
            outputs.push(io("loss", &[]));
            add(&format!("train_{mode}"), inputs, outputs);
        }

        let tap_names = builtin_tap_names(&cfg);
        let ntok = cfg.eval_batch * cfg.seq_len;
        add(
            "calib_stats",
            base.iter().cloned().chain([tok_eval.clone()]).collect(),
            tap_names
                .iter()
                .map(|n| {
                    let d_in = shapes[n.as_str()][1];
                    io(format!("gram::{n}"), &[d_in, d_in])
                })
                .collect(),
        );
        add(
            "capture_inputs",
            base.iter().cloned().chain([tok_eval.clone()]).collect(),
            tap_names
                .iter()
                .map(|n| io(format!("x::{n}"), &[ntok, shapes[n.as_str()][1]]))
                .collect(),
        );

        let mut lin_shapes: Vec<(usize, usize)> = prunable
            .iter()
            .map(|n| (shapes[n.as_str()][0], shapes[n.as_str()][1]))
            .collect();
        lin_shapes.sort();
        lin_shapes.dedup();
        let (rows, r) = (cfg.calib_rows, cfg.lora_rank);
        for (o, i) in lin_shapes {
            let tag = format!("{o}x{i}");
            add(
                &format!("linear_fwd_{tag}"),
                vec![io("x", &[rows, i]), io("w", &[o, i])],
                vec![io("y0", &[rows, o])],
            );
            add(
                &format!("recon_masklora_{tag}"),
                vec![
                    io("x", &[rows, i]),
                    io("y0", &[rows, o]),
                    io("w", &[o, i]),
                    io("mask", &[o, i]),
                    io("a", &[r, i]),
                    io("b", &[o, r]),
                    io("om::a", &[r, i]),
                    io("ov::a", &[r, i]),
                    io("om::b", &[o, r]),
                    io("ov::b", &[o, r]),
                    io("step", &[]),
                    io("lr", &[]),
                ],
                vec![
                    io("o::a", &[r, i]),
                    io("o::b", &[o, r]),
                    io("om::a", &[r, i]),
                    io("ov::a", &[r, i]),
                    io("om::b", &[o, r]),
                    io("ov::b", &[o, r]),
                    io("loss", &[]),
                ],
            );
            add(
                &format!("recon_full_{tag}"),
                vec![
                    io("x", &[rows, i]),
                    io("y0", &[rows, o]),
                    io("w", &[o, i]),
                    io("mask", &[o, i]),
                    io("om::w", &[o, i]),
                    io("ov::w", &[o, i]),
                    io("step", &[]),
                    io("lr", &[]),
                ],
                vec![
                    io("o::w", &[o, i]),
                    io("om::w", &[o, i]),
                    io("ov::w", &[o, i]),
                    io("loss", &[]),
                ],
            );
        }

        // ---- serving: KV-cache prefill + single-token decode ----------
        // `prefill` runs the full padded forward over up to `serve_slots`
        // prompts and emits last-valid-position logits plus every layer's
        // K/V planes; `decode_step` advances each active stream by one
        // token against those caches, returning only the new K/V rows (the
        // server owns the cache and writes them in place).
        let slots = cfg.serve_slots;
        let (nh, dh) = (cfg.n_heads, cfg.d_model / cfg.n_heads);
        let kv_planes: Vec<IoSpec> = (0..cfg.n_layers)
            .flat_map(|i| {
                [
                    io(format!("k::h{i}"), &[slots, nh, cfg.seq_len, dh]),
                    io(format!("v::h{i}"), &[slots, nh, cfg.seq_len, dh]),
                ]
            })
            .collect();
        add(
            "prefill",
            base.iter()
                .cloned()
                .chain([io_i32("tokens", &[slots, cfg.seq_len]), io_i32("lens", &[slots])])
                .collect(),
            std::iter::once(io("logits", &[slots, cfg.vocab]))
                .chain(kv_planes.iter().cloned())
                .collect(),
        );
        add(
            "decode_step",
            base.iter()
                .cloned()
                .chain(kv_planes.iter().cloned())
                .chain([io_i32("tokens", &[slots]), io_i32("pos", &[slots])])
                .collect(),
            std::iter::once(io("logits", &[slots, cfg.vocab]))
                .chain((0..cfg.n_layers).flat_map(|i| {
                    [
                        io(format!("knew::h{i}"), &[slots, nh, dh]),
                        io(format!("vnew::h{i}"), &[slots, nh, dh]),
                    ]
                }))
                .collect(),
        );
        // `verify_step` scores up to `spec_width` consecutive tokens per
        // stream in one pass over the target KV cache — the batched check of
        // a speculative draft.  `klen[b]` carries the actual token count for
        // slot b (rows beyond it are padding); logits row j scores position
        // pos[b]+j, and the server commits/rolls back via the returned
        // per-position K/V rows.
        let sw = cfg.spec_width;
        add(
            "verify_step",
            base.iter()
                .cloned()
                .chain(kv_planes.iter().cloned())
                .chain([
                    io_i32("tokens", &[slots, sw]),
                    io_i32("pos", &[slots]),
                    io_i32("klen", &[slots]),
                ])
                .collect(),
            std::iter::once(io("logits", &[slots, sw, cfg.vocab]))
                .chain((0..cfg.n_layers).flat_map(|i| {
                    [
                        io(format!("knew::h{i}"), &[slots, sw, nh, dh]),
                        io(format!("vnew::h{i}"), &[slots, sw, nh, dh]),
                    ]
                }))
                .collect(),
        );

        ModelManifest { cfg, params, prunable, taps, adapters, trainable, executables }
    }
}

impl Manifest {
    /// The hermetic manifest for the whole builtin fleet — what the native
    /// backend executes against.  No filesystem access.
    pub fn builtin() -> Manifest {
        let models = ModelCfg::BUILTIN_NAMES
            .iter()
            .map(|n| (n.to_string(), ModelManifest::builtin(ModelCfg::builtin(n).unwrap())))
            .collect();
        Manifest { dir: PathBuf::from("<builtin>"), models }
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.req("models").as_obj().context("models")? {
            models.insert(name.clone(), parse_model(mj)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| {
                format!(
                    "model {name:?} not in manifest; available: {:?}",
                    self.models.keys().collect::<Vec<_>>()
                )
            })
    }

    pub fn hlo_path(&self, exec: &ExecSpec) -> PathBuf {
        self.dir.join(&exec.file)
    }
}

fn parse_model(j: &Json) -> Result<ModelManifest> {
    let c = j.req("config");
    let cfg = ModelCfg {
        name: c.req("name").as_str().unwrap().to_string(),
        vocab: c.req("vocab").as_usize().unwrap(),
        d_model: c.req("d_model").as_usize().unwrap(),
        n_layers: c.req("n_layers").as_usize().unwrap(),
        n_heads: c.req("n_heads").as_usize().unwrap(),
        seq_len: c.req("seq_len").as_usize().unwrap(),
        d_ff: c.req("d_ff").as_usize().unwrap(),
        use_bias: c.req("use_bias").as_bool().unwrap(),
        norm: c.req("norm").as_str().unwrap().to_string(),
        lora_rank: c.req("lora_rank").as_usize().unwrap(),
        lora_alpha: c.req("lora_alpha").as_f64().unwrap(),
        lora_scale: c.req("lora_scale").as_f64().unwrap(),
        train_batch: c.req("train_batch").as_usize().unwrap(),
        eval_batch: c.req("eval_batch").as_usize().unwrap(),
        calib_rows: c.req("calib_rows").as_usize().unwrap(),
        // older aot.py manifests predate the serving executables
        serve_slots: c.get("serve_slots").and_then(Json::as_usize).unwrap_or(8),
        spec_width: c.get("spec_width").and_then(Json::as_usize).unwrap_or(8),
    };
    let params = j
        .req("params")
        .as_arr()
        .context("params")?
        .iter()
        .map(|p| ParamSpec {
            name: p.req("name").as_str().unwrap().to_string(),
            shape: p
                .req("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect(),
            group: p.req("group").as_str().unwrap().to_string(),
        })
        .collect();
    let prunable = j
        .req("prunable")
        .as_arr()
        .context("prunable")?
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect();
    let mut taps = BTreeMap::new();
    for (k, v) in j.req("taps").as_obj().context("taps")? {
        taps.insert(k.clone(), v.as_str().unwrap().to_string());
    }
    let adapters = j
        .req("adapters")
        .as_arr()
        .context("adapters")?
        .iter()
        .map(|a| {
            (
                a.req("name").as_str().unwrap().to_string(),
                a.req("shape")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_usize().unwrap())
                    .collect(),
            )
        })
        .collect();
    let mut trainable = BTreeMap::new();
    for (mode, names) in j.req("trainable").as_obj().context("trainable")? {
        trainable.insert(
            mode.clone(),
            names
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_str().unwrap().to_string())
                .collect(),
        );
    }
    let mut executables = BTreeMap::new();
    for (name, e) in j.req("executables").as_obj().context("executables")? {
        let inputs = e
            .req("inputs")
            .as_arr()
            .unwrap()
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = e
            .req("outputs")
            .as_arr()
            .unwrap()
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        executables.insert(
            name.clone(),
            ExecSpec {
                name: name.clone(),
                file: e.req("file").as_str().unwrap().to_string(),
                inputs,
                outputs,
            },
        );
    }
    Ok(ModelManifest { cfg, params, prunable, taps, adapters, trainable, executables })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_fleet_is_complete() {
        let m = Manifest::builtin();
        assert_eq!(m.models.len(), ModelCfg::BUILTIN_NAMES.len());
        let nano = m.model("gpt-nano").unwrap();
        assert_eq!(nano.cfg.d_model, 32);
        assert_eq!(nano.cfg.d_head(), 16);
        assert_eq!(nano.prunable.len(), nano.cfg.n_layers * 6);
        assert!(nano.exec("eval_loss").is_ok());
        assert!(nano.exec("train_masklora").is_ok());
        assert!(nano.exec("linear_fwd_32x32").is_ok());
        assert!(nano.exec("recon_masklora_128x32").is_ok()); // (d_ff, d) fc
        assert!(nano.exec("recon_masklora_32x128").is_ok()); // (d, d_ff) proj
        assert!(nano.exec("recon_full_32x32").is_ok());
        assert!(nano.exec("prefill").is_ok());
        assert!(nano.exec("decode_step").is_ok());
        assert!(nano.exec("verify_step").is_ok());
        assert!(nano.exec("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn serving_executables_carry_kv_planes() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let cfg = &mm.cfg;
        let (slots, nh, dh) = (cfg.serve_slots, cfg.n_heads, cfg.d_head());
        let p = mm.exec("prefill").unwrap();
        // params + masks + tokens + lens in; logits + 2 planes per layer out
        assert_eq!(p.inputs.len(), mm.params.len() + mm.prunable.len() + 2);
        assert_eq!(p.outputs.len(), 1 + 2 * cfg.n_layers);
        assert_eq!(p.outputs[0].shape, vec![slots, cfg.vocab]);
        assert_eq!(p.outputs[1].name, "k::h0");
        assert_eq!(p.outputs[1].shape, vec![slots, nh, cfg.seq_len, dh]);
        let d = mm.exec("decode_step").unwrap();
        // cache planes are inputs; only the new rows come back
        assert_eq!(
            d.inputs.len(),
            mm.params.len() + mm.prunable.len() + 2 * cfg.n_layers + 2
        );
        assert_eq!(d.outputs.len(), 1 + 2 * cfg.n_layers);
        let knew = d.outputs.iter().find(|o| o.name == "knew::h1").unwrap();
        assert_eq!(knew.shape, vec![slots, nh, dh]);
        let tok = d.inputs.iter().find(|i| i.name == "tokens").unwrap();
        assert_eq!(tok.dtype, DType::I32);
        assert_eq!(tok.shape, vec![slots]);
        let v = mm.exec("verify_step").unwrap();
        // decode_step's planes plus a klen vector; logits widen to spec_width
        assert_eq!(
            v.inputs.len(),
            mm.params.len() + mm.prunable.len() + 2 * cfg.n_layers + 3
        );
        assert_eq!(v.outputs.len(), 1 + 2 * cfg.n_layers);
        assert_eq!(v.outputs[0].shape, vec![slots, cfg.spec_width, cfg.vocab]);
        let vt = v.inputs.iter().find(|i| i.name == "tokens").unwrap();
        assert_eq!(vt.shape, vec![slots, cfg.spec_width]);
        let vk = v.outputs.iter().find(|o| o.name == "knew::h1").unwrap();
        assert_eq!(vk.shape, vec![slots, cfg.spec_width, nh, dh]);
    }

    #[test]
    fn executable_io_tables_are_consistent() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        // eval_loss takes every param, every mask and i32 tokens
        let e = mm.exec("eval_loss").unwrap();
        assert_eq!(e.inputs.len(), mm.params.len() + mm.prunable.len() + 1);
        let tok = e.inputs.last().unwrap();
        assert_eq!(tok.dtype, DType::I32);
        assert_eq!(tok.shape, vec![mm.cfg.eval_batch, mm.cfg.seq_len]);
        // train_biases round-trips its leaves: o::/om::/ov:: per trainable
        let t = mm.exec("train_biases").unwrap();
        let n_leaves = mm.trainable["biases"].len();
        assert_eq!(t.outputs.len(), 3 * n_leaves + 1);
        assert_eq!(t.outputs.last().unwrap().name, "loss");
        // train_masklora additionally carries the adapter pairs
        let tm = mm.exec("train_masklora").unwrap();
        let n_lora_leaves = mm.trainable["masklora"].len() + mm.adapters.len();
        assert_eq!(tm.outputs.len(), 3 * n_lora_leaves + 1);
        // calib_stats emits one Gram per tap with the input dim squared
        let c = mm.exec("calib_stats").unwrap();
        assert_eq!(c.outputs.len(), mm.cfg.n_layers * 4);
        for o in &c.outputs {
            let lin = o.name.strip_prefix("gram::").unwrap();
            let d_in = mm.param_shape(lin)[1];
            assert_eq!(o.shape, vec![d_in, d_in]);
        }
    }

    #[test]
    fn taps_share_qkv_inputs() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-tiny").unwrap();
        assert_eq!(mm.taps["h0_attn_k_w"], "h0_attn_q_w");
        assert_eq!(mm.taps["h0_attn_v_w"], "h0_attn_q_w");
        assert_eq!(mm.taps["h1_mlp_fc_w"], "h1_mlp_fc_w");
        for tap in builtin_tap_names(&mm.cfg) {
            assert!(mm.param(&tap).is_some(), "{tap}");
        }
    }

    #[test]
    fn trainable_fractions_match_paper_frame() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-small").unwrap();
        let total = mm.total_params() as f64;
        let ln = mm.trainable_count("ln") as f64 / total;
        let biases = mm.trainable_count("biases") as f64 / total;
        let lora = mm.trainable_count("masklora") as f64 / total;
        assert!(ln < biases && biases < lora && lora < 0.2, "{ln} {biases} {lora}");
        assert_eq!(mm.trainable_count("full"), mm.total_params());
    }

    #[test]
    fn llama_has_no_bias_group() {
        let m = Manifest::builtin();
        let lm = m.model("llama-tiny").unwrap();
        assert_eq!(lm.trainable_count("biases"), 0);
        assert!(!lm.cfg.use_bias);
        assert_eq!(lm.cfg.norm, "rmsnorm");
        // and no bias inputs anywhere in its train executables
        let t = lm.exec("train_full").unwrap();
        assert!(t.inputs.iter().all(|i| !i.name.ends_with("_b")));
    }
}
