//! Metrics: throughput meters and the analytical memory model.
//!
//! The memory model reproduces the paper's headline systems claim — "prune
//! and retrain a 30B model on a *single* A100" — as arithmetic: weights at
//! bf16 plus grads + AdamW moments *only for the trainable subset*, plus the
//! activation term (which layer-wise reconstruction shrinks to one block).

use std::time::Instant;

/// Tokens-per-second meter for retraining loops (Table 4).
#[derive(Debug)]
pub struct TpsMeter {
    start: Instant,
    tokens: u64,
}

impl Default for TpsMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl TpsMeter {
    pub fn new() -> TpsMeter {
        TpsMeter { start: Instant::now(), tokens: 0 }
    }
    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }
    pub fn tokens(&self) -> u64 {
        self.tokens
    }
    pub fn tps(&self) -> f64 {
        self.tokens as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
    pub fn reset(&mut self) {
        self.start = Instant::now();
        self.tokens = 0;
    }
}

/// Byte-level footprint of one retraining configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBreakdown {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer: u64,
    pub activations: u64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer + self.activations
    }
    pub fn gib(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

/// Analytical memory model.
///
/// * weights: `total_params` at `weight_bytes` (2 = bf16, the LLM default);
/// * grads: trainable params at 4 bytes (f32 master grads);
/// * optimizer: 2 AdamW moments at 4 bytes per trainable param;
/// * activations: `2 * tokens * d_model * n_layers * 4` for full backprop
///   (attention + MLP residual streams), scaled down to a single block for
///   layer-wise reconstruction.
pub fn training_memory(
    total_params: u64,
    trainable_params: u64,
    tokens_per_batch: u64,
    d_model: u64,
    n_layers: u64,
    weight_bytes: u64,
    layerwise: bool,
) -> MemoryBreakdown {
    let act_layers = if layerwise { 1 } else { n_layers };
    MemoryBreakdown {
        weights: total_params * weight_bytes,
        gradients: trainable_params * 4,
        optimizer: trainable_params * 8,
        activations: 2 * tokens_per_batch * d_model * act_layers * 4,
    }
}

/// The paper-scale sanity table: OPT-30B on an 80 GiB A100.
pub fn opt30b_fits_table() -> Vec<(String, f64, bool)> {
    const A100: f64 = 80.0;
    let total = 30_000_000_000u64;
    let rows = [
        ("Full FT", total),
        ("MaskLoRA (0.33%)", total / 304),
        ("Biases (0.013%)", total / 7692),
        ("LN (0.005%)", total / 20000),
    ];
    rows.iter()
        .map(|(name, trainable)| {
            let mem = training_memory(total, *trainable, 2 * 2048, 7168, 48, 2, false);
            (name.to_string(), mem.gib(), mem.gib() < A100)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_counts() {
        let mut m = TpsMeter::new();
        m.add_tokens(100);
        m.add_tokens(24);
        assert_eq!(m.tokens(), 124);
        assert!(m.tps() > 0.0);
    }

    #[test]
    fn memory_scales_with_trainable_fraction() {
        let full = training_memory(1_000_000, 1_000_000, 1024, 512, 8, 2, false);
        let ln = training_memory(1_000_000, 100, 1024, 512, 8, 2, false);
        assert_eq!(full.weights, ln.weights);
        assert!(full.total() > ln.total());
        // optimizer state scales exactly with the trainable fraction
        assert_eq!(full.optimizer, 10_000 * ln.optimizer);
        assert_eq!(full.gradients, 10_000 * ln.gradients);
    }

    #[test]
    fn layerwise_shrinks_activations() {
        let global = training_memory(1_000_000, 1000, 1024, 512, 8, 2, false);
        let layer = training_memory(1_000_000, 1000, 1024, 512, 8, 2, true);
        assert_eq!(layer.activations * 8, global.activations);
    }

    #[test]
    fn paper_scale_claim_reproduced() {
        // full FT of 30B must NOT fit; every PERP subset must fit.
        let table = opt30b_fits_table();
        assert!(!table[0].2, "full FT should exceed one A100: {:.0} GiB", table[0].1);
        for row in &table[1..] {
            assert!(row.2, "{} should fit: {:.0} GiB", row.0, row.1);
        }
    }
}
