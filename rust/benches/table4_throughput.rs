//! `cargo bench --bench table4_throughput` — regenerates the paper's table4
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("table4");
}
