//! Deterministic PRNG (rand-crate replacement).
//!
//! xoshiro256++ seeded through SplitMix64 — the standard, well-tested
//! combination.  Every stochastic component of the pipeline (corpus
//! generation, init, batch sampling, adapter init, property tests) threads an
//! explicit [`Rng`] so whole experiments replay bit-exactly from one seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per-experiment, per-tensor).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(x) = self.spare.take() {
            return x;
        }
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u64) as usize]
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let v = r.normal_vec(50_000, 1.0);
        let mean = v.iter().sum::<f32>() / v.len() as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..5_000 {
            c[r.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > 5 * c[0]);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
