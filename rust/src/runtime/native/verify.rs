//! Speculative `verify_step`: score up to `spec_width` consecutive tokens
//! per stream in one pass over the target KV cache.
//!
//! The batcher feeds each spec stream its already-committed last token plus
//! the draft's proposals — `klen[b]` tokens occupying absolute positions
//! `pos[b] .. pos[b]+klen[b]` — and this executable returns the target's
//! logits for *every* one of those positions, so the longest accepted
//! prefix falls out of one forward instead of `klen` sequential
//! `decode_step` calls.  Rows are compacted across streams exactly like
//! `decode_step` compacts active slots: a round with two streams of three
//! proposals each runs the per-layer linears over 8 rows, not
//! `slots * spec_width`.
//!
//! Attention is the only stage where the multi-token shape matters: the
//! query at absolute position `pos[b]+jq` scores the cache rows `0..pos[b]`
//! plus this pass's own fresh K rows at `pos[b]..=pos[b]+jq` (causal within
//! the speculated window).  Scores accumulate in ascending position order
//! with the same running-max softmax as `decode::attend`, and every linear
//! reuses `decode`'s per-output-element kernels, so each logits row is
//! bitwise what a sequential greedy `decode_step` at that position would
//! produce — the foundation of the spec engine's exactness guarantee,
//! pinned end-to-end by `tests/decode_parity.rs`.

use std::collections::BTreeMap;

use anyhow::Result;
use rayon::prelude::*;

use crate::runtime::manifest::ModelManifest;
use crate::runtime::Outputs;
use crate::tensor::{linalg, pool, Tensor};

use super::decode::{fused_qkv, linear_apply, norm_apply};
use super::graph::{GraphIn, ModeKind, SparseView};
use super::ops;

pub(super) fn verify_step(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
) -> Result<Outputs> {
    let cfg = &mm.cfg;
    let (nh, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
    let (slots, seq, vocab, sw) = (cfg.serve_slots, cfg.seq_len, cfg.vocab, cfg.spec_width);
    let (params, masks) = super::gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (_, toks) = i32s["tokens"];
    let (_, pos) = i32s["pos"];
    let (_, klen) = i32s["klen"];

    // Compact (slot, offset) rows: row r below belongs to stream
    // `rows[r].0` at window offset `rows[r].1`.  `base[b]` is slot b's
    // first compacted row — attention uses it to reach the stream's own
    // fresh K/V rows for positions at or beyond `pos[b]`.
    let mut rows: Vec<(usize, usize)> = Vec::new();
    let mut base = vec![usize::MAX; slots];
    for b in 0..slots {
        let (p, kl) = (pos[b], klen[b]);
        if p < 0 || kl < 1 {
            continue;
        }
        let (p, kl) = (p as usize, (kl as usize).min(sw));
        if p + kl > seq {
            continue; // would overrun the cache plane: slot sits this round out
        }
        base[b] = rows.len();
        rows.extend((0..kl).map(|j| (b, j)));
    }
    crate::count!("decode.verify_steps");
    crate::count!("decode.verify_rows", rows.len() as u64);

    let mut out_logits = pool::zeroed(slots * sw * vocab);
    let mut knew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * sw * nh * dh)).collect();
    let mut vnew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * sw * nh * dh)).collect();

    if !rows.is_empty() {
        let na = rows.len();
        let embt = gi.p("embed_tokens");
        let post = gi.p("embed_pos");
        let mut x = pool::zeroed(na * d);
        for (r, &(b, j)) in rows.iter().enumerate() {
            let tok = (toks[b * sw + j].max(0) as usize).min(vocab - 1);
            let p = pos[b] as usize + j;
            let erow = &embt.data()[tok * d..(tok + 1) * d];
            let prow = &post.data()[p * d..(p + 1) * d];
            for c in 0..d {
                x[r * d + c] = erow[c] + prow[c];
            }
        }
        let mut cur = Tensor::new(&[na, d], x);

        for i in 0..cfg.n_layers {
            let pfx = format!("h{i}_");
            let h1 = norm_apply(&gi, &format!("{pfx}ln1"), &cur);
            let (q, k, v) = match fused_qkv(&gi, &pfx, &h1) {
                Some(heads) => heads,
                None => (
                    linear_apply(&gi, &format!("{pfx}attn_q"), &h1),
                    linear_apply(&gi, &format!("{pfx}attn_k"), &h1),
                    linear_apply(&gi, &format!("{pfx}attn_v"), &h1),
                ),
            };
            pool::recycle(h1);
            for (r, &(b, j)) in rows.iter().enumerate() {
                for hd in 0..nh {
                    let src = r * d + hd * dh;
                    let dst = ((b * sw + j) * nh + hd) * dh;
                    knew[i][dst..dst + dh].copy_from_slice(&k.data()[src..src + dh]);
                    vnew[i][dst..dst + dh].copy_from_slice(&v.data()[src..src + dh]);
                }
            }
            let kc = f32s[format!("k::h{i}").as_str()];
            let vc = f32s[format!("v::h{i}").as_str()];
            let merged = attend_multi(&q, &k, &v, kc, vc, &rows, &base, pos, nh, dh, seq);
            pool::recycle(q);
            pool::recycle(k);
            pool::recycle(v);
            let o = linear_apply(&gi, &format!("{pfx}attn_o"), &merged);
            pool::recycle(merged);
            let res_mid = cur.add(&o);
            pool::recycle(cur);
            pool::recycle(o);
            let h2 = norm_apply(&gi, &format!("{pfx}ln2"), &res_mid);
            let fc = linear_apply(&gi, &format!("{pfx}mlp_fc"), &h2);
            pool::recycle(h2);
            let g = ops::gelu(&fc);
            pool::recycle(fc);
            let proj = linear_apply(&gi, &format!("{pfx}mlp_proj"), &g);
            pool::recycle(g);
            cur = res_mid.add(&proj);
            pool::recycle(res_mid);
            pool::recycle(proj);
        }

        let hf = norm_apply(&gi, "final_ln", &cur);
        pool::recycle(cur);
        let logits = linalg::matmul_nt(&hf, gi.p("head_w"));
        pool::recycle(hf);
        for (r, &(b, j)) in rows.iter().enumerate() {
            let dst = (b * sw + j) * vocab;
            out_logits[dst..dst + vocab]
                .copy_from_slice(&logits.data()[r * vocab..(r + 1) * vocab]);
        }
        pool::recycle(logits);
    }

    let mut values =
        vec![("logits".to_string(), Tensor::new(&[slots, sw, vocab], out_logits))];
    for (i, (kn, vn)) in knew.into_iter().zip(vnew).enumerate() {
        values.push((format!("knew::h{i}"), Tensor::new(&[slots, sw, nh, dh], kn)));
        values.push((format!("vnew::h{i}"), Tensor::new(&[slots, sw, nh, dh], vn)));
    }
    Ok(Outputs { values })
}

/// Causal attention across the speculated window.  Query row `r = (b, jq)`
/// sits at absolute position `pos[b]+jq` and scores positions
/// `0..=pos[b]+jq`: cache rows below `pos[b]`, this pass's fresh K/V rows
/// (compacted at `base[b] + (idx - pos[b])`) at or above it.  Position
/// order, running-max softmax, and the j-ascending weighted-V accumulation
/// mirror `decode::attend` exactly — same dots, same order, same bits.
#[allow(clippy::too_many_arguments)]
fn attend_multi(
    q: &Tensor,
    knew: &Tensor,
    vnew: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    rows: &[(usize, usize)],
    base: &[usize],
    pos: &[i32],
    nh: usize,
    dh: usize,
    seq: usize,
) -> Tensor {
    let na = rows.len();
    let d = nh * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = pool::zeroed(na * d);
    let (qd, knd, vnd) = (q.data(), knew.data(), vnew.data());
    let (kcd, vcd) = (kc.data(), vc.data());
    out.par_chunks_mut(d).enumerate().for_each(|(r, orow)| {
        let (b, jq) = rows[r];
        let p = pos[b] as usize; // cache rows 0..p valid; window starts at p
        let ap = p + jq; // absolute query position
        for hd in 0..nh {
            let qv = &qd[r * d + hd * dh..r * d + (hd + 1) * dh];
            let cbase = b * nh * seq * dh + hd * seq * dh;
            let mut row = vec![0.0f32; ap + 1];
            let mut mx = f32::NEG_INFINITY;
            for (j, rj) in row.iter_mut().enumerate() {
                let kj: &[f32] = if j < p {
                    &kcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    let nr = base[b] + (j - p);
                    &knd[nr * d + hd * dh..nr * d + (hd + 1) * dh]
                };
                let dot: f32 = qv.iter().zip(kj).map(|(&a, &c)| a * c).sum();
                *rj = dot * scale;
                mx = mx.max(*rj);
            }
            let mut denom = 0.0f32;
            for rj in row.iter_mut() {
                *rj = (*rj - mx).exp();
                denom += *rj;
            }
            let orow_h = &mut orow[hd * dh..(hd + 1) * dh];
            for (j, &rj) in row.iter().enumerate() {
                let pj = rj / denom;
                let vj: &[f32] = if j < p {
                    &vcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    let nr = base[b] + (j - p);
                    &vnd[nr * d + hd * dh..nr * d + (hd + 1) * dh]
                };
                for (o, &vv) in orow_h.iter_mut().zip(vj) {
                    *o += pj * vv;
                }
            }
        }
    });
    Tensor::new(&[na, d], out)
}
