"""L1 Pallas kernels for PERP (interpret=True; see common.py).

Public surface used by the L2 model (compile/model.py):

* matmul.mm_nt / mm_nn / masked_matmul — dense + pruned linears
* masked_lora.masked_lora_matmul       — MaskLoRA fused forward/backward
* scale_lora.scale_lora_matmul         — ScaleLoRA fused forward/backward
* attention.attention                  — causal flash-style attention
* layernorm.layernorm / rmsnorm        — affine norms (the LN subset)
* adamw.adamw_update                   — fused optimizer step
* masks.*                              — device-side mask/score kernels
* ref.*                                — pure-jnp oracles (tests only)
"""

from . import ref  # noqa: F401
from .adamw import adamw_update  # noqa: F401
from .attention import attention  # noqa: F401
from .layernorm import layernorm, rmsnorm  # noqa: F401
from .masked_lora import masked_lora_matmul  # noqa: F401
from .masks import magnitude_threshold_mask, nm_mask, wanda_score  # noqa: F401
from .matmul import dmm_nt, masked_matmul, mm_nn, mm_nt  # noqa: F401
from .scale_lora import scale_lora_init, scale_lora_matmul  # noqa: F401
