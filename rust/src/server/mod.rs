//! `perp-serve`: the inference-serving subsystem.
//!
//! `repro serve` boots this stack: a hand-rolled HTTP/1.1 server over
//! `std::net::TcpListener` (zero native deps, matching the rest of the
//! crate) with a worker-thread pool, fronting one [`batcher`] engine thread
//! per loaded model variant.  Engines own all model state — weights loaded
//! through [`crate::coordinator::Session`], per-stream [`kv`] cache slots,
//! and the backend — and decode concurrent `/generate` streams in lock-step
//! through the `prefill`/`decode_step` executables.
//!
//! * [`ServeState`] — the variant registry.  Multiple checkpoints (dense,
//!   pruned-at-sparsity-s, merged adapters) are hot-loadable behind one
//!   process via `POST /models/load`.
//! * [`Server`] — accept loop + worker pool; `run` blocks (the CLI path),
//!   `spawn` returns a stoppable handle (tests and `repro bench-serve`).
//! * [`client`] — the minimal HTTP client the load generator and the
//!   integration tests drive the server with.

pub mod batcher;
pub mod client;
pub mod kv;
pub mod router;
pub mod spec;

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::jobs::JobManager;

pub use batcher::{BatchCfg, EngineHandle, EngineSpec, GenResult, ScoreResult};

// ---------------------------------------------------------------------------
// ServeState: the model-variant registry.
// ---------------------------------------------------------------------------

pub struct ServeState {
    engines: Mutex<BTreeMap<String, Arc<EngineHandle>>>,
    /// Variant `/generate` falls back to when the request names none.
    pub default_model: String,
    /// Template config for hot-loaded variants (model key overridable).
    pub base_cfg: ExperimentConfig,
    /// Dense-checkpoint cache directory for engines without `--from`.
    pub cache_dir: PathBuf,
    pub seed: u64,
    pub started: Instant,
    pub http_requests: AtomicU64,
    /// Job queue behind the `/jobs` endpoints — set by `repro daemon`,
    /// absent under plain `repro serve` (those routes then answer 503).
    jobs: OnceLock<Arc<JobManager>>,
    /// The process-wide stop flag: the accept loop polls it, and
    /// [`request_shutdown`] (signal handlers, `POST /shutdown`,
    /// [`ServerHandle::stop`]) sets it.
    pub stop: Arc<AtomicBool>,
    /// Bound listen address, set by [`Server::bind`] — lets
    /// [`request_shutdown`] self-connect to wake the blocking accept.
    bound: OnceLock<SocketAddr>,
}

impl ServeState {
    pub fn new(
        default_model: String,
        base_cfg: ExperimentConfig,
        cache_dir: PathBuf,
        seed: u64,
    ) -> ServeState {
        ServeState {
            engines: Mutex::new(BTreeMap::new()),
            default_model,
            base_cfg,
            cache_dir,
            seed,
            started: Instant::now(),
            http_requests: AtomicU64::new(0),
            jobs: OnceLock::new(),
            stop: Arc::new(AtomicBool::new(false)),
            bound: OnceLock::new(),
        }
    }

    /// Attach the daemon's job queue (once, before serving).
    pub fn set_jobs(&self, mgr: Arc<JobManager>) {
        let _ = self.jobs.set(mgr);
    }

    pub fn jobs(&self) -> Option<&Arc<JobManager>> {
        self.jobs.get()
    }

    pub fn insert(&self, handle: Arc<EngineHandle>) -> Result<()> {
        let mut g = self.engines.lock().unwrap();
        if g.contains_key(&handle.name) {
            bail!("variant {:?} already loaded", handle.name);
        }
        g.insert(handle.name.clone(), handle);
        Ok(())
    }

    pub fn engine(&self, name: &str) -> Option<Arc<EngineHandle>> {
        self.engines.lock().unwrap().get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.engines.lock().unwrap().keys().cloned().collect()
    }

    pub fn engines_snapshot(&self) -> Vec<Arc<EngineHandle>> {
        self.engines.lock().unwrap().values().cloned().collect()
    }

    /// Ask every engine thread to exit (pending work is abandoned).
    pub fn shutdown(&self) {
        for e in self.engines_snapshot() {
            e.shutdown();
        }
    }
}

/// Begin graceful shutdown: idempotently set the stop flag, stop the job
/// queue from dequeuing (running jobs get their cancel flags set and
/// requeue themselves for the next boot), and self-connect the listener so
/// the blocking accept loop observes the flag.  Safe from any thread —
/// signal watchdogs, HTTP workers (`POST /shutdown`), test harnesses.
pub fn request_shutdown(state: &ServeState) {
    if state.stop.swap(true, Ordering::SeqCst) {
        return; // already shutting down
    }
    if let Some(jobs) = state.jobs() {
        jobs.begin_shutdown();
    }
    if let Some(addr) = state.bound.get() {
        let _ = TcpStream::connect(addr); // wake the accept loop
    }
}

// ---------------------------------------------------------------------------
// Server: accept loop + worker pool.
// ---------------------------------------------------------------------------

pub struct Server {
    listener: TcpListener,
    pub addr: SocketAddr,
    state: Arc<ServeState>,
    workers: usize,
}

impl Server {
    /// Bind (use port 0 for an ephemeral port) with `workers` HTTP threads.
    pub fn bind(state: Arc<ServeState>, addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let addr = listener.local_addr()?;
        let _ = state.bound.set(addr);
        Ok(Server { listener, addr, state, workers: workers.max(1) })
    }

    /// Run the accept loop on the current thread.  Returns once the
    /// state's stop flag is set *and* a connection arrives to wake the
    /// loop — [`request_shutdown`] does both.
    pub fn run(self) {
        let stop = self.state.stop.clone();
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut joins = Vec::with_capacity(self.workers);
        for i in 0..self.workers {
            let rx = rx.clone();
            let state = self.state.clone();
            let join = thread::Builder::new()
                .name(format!("http-{i}"))
                .spawn(move || loop {
                    // hold the lock only while waiting for the next socket
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(mut stream) => router::serve_connection(&state, &mut stream),
                        Err(_) => break, // acceptor is gone
                    }
                })
                .expect("spawning http worker");
            joins.push(join);
        }
        for conn in self.listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let _ = tx.send(stream);
                }
                Err(e) => crate::warn!("accept error: {e}"),
            }
        }
        drop(tx);
        for j in joins {
            let _ = j.join();
        }
    }

    /// Run the accept loop on a background thread and return a stoppable
    /// handle — the harness for tests and `repro bench-serve`.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.addr;
        let state = self.state.clone();
        let join = thread::spawn(move || self.run());
        ServerHandle { addr, state, join: Some(join) }
    }
}

pub struct ServerHandle {
    pub addr: SocketAddr,
    pub state: Arc<ServeState>,
    join: Option<thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Stop the accept loop, join the workers and shut the engines down.
    pub fn stop(mut self) {
        request_shutdown(&self.state);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.state.shutdown();
    }

    /// Wait for the accept loop to exit on its own (e.g. after a
    /// `POST /shutdown`), then shut the engines down.
    pub fn join(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.state.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_state() -> Arc<ServeState> {
        Arc::new(ServeState::new(
            "gpt-nano".to_string(),
            ExperimentConfig::quick("gpt-nano"),
            std::env::temp_dir().join("perp_serve_state_test"),
            0,
        ))
    }

    #[test]
    fn registry_rejects_duplicates_and_lists_names() {
        let state = empty_state();
        assert!(state.names().is_empty());
        assert!(state.engine("nope").is_none());
    }

    #[test]
    fn server_binds_ephemeral_port_and_stops() {
        let state = empty_state();
        let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
        let addr = server.addr;
        assert_ne!(addr.port(), 0);
        let handle = server.spawn();
        // a health check against an engine-less registry still routes
        let (status, body) = client::get(addr, "/healthz").unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"status\":\"ok\""));
        handle.stop();
    }
}
