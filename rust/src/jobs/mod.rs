//! Durable experiment jobs: a persistent queue of plan-graph runs served
//! by `repro daemon`.
//!
//! PERP experimentation is many graphs over days — criteria × sparsities ×
//! retrain budgets — not one foreground `repro run`.  This subsystem turns
//! the repro into a small experiment service:
//!
//! * [`store`] — the durable truth: one `job.json` per job under
//!   `<out>/jobs/<id>/`, holding the submitted graph, the *resolved*
//!   [`ExperimentConfig`](crate::config::ExperimentConfig) (bit-exact JSON
//!   round-trip ⇒ bit-identical cache keys on resume), per-node status
//!   keyed by the executor's FNV stage keys, and final aggregate rows.
//! * [`queue`] — [`queue::JobManager`]: the rebuildable in-memory view.
//!   Boot rescans the store, requeues every non-terminal job (interrupted
//!   `running` jobs reset their running nodes and resume through the stage
//!   cache), then mediates submit/dequeue/cancel under one mutex+condvar.
//! * [`worker`] — [`worker::JobRunner`]: dequeue → execute with the
//!   plan-graph [`Executor`](crate::pipeline::Executor), wired to the
//!   job's cancel flag and a node hook that persists per-node progress on
//!   every event.  Serial jobs hold one kernel-budget share so concurrent
//!   jobs split threads instead of oversubscribing.
//! * [`api`] — HTTP shapes: submit-body parsing/validation and
//!   summary/detail rendering for the `/jobs` endpoints.
//!
//! Durability contract: a `SIGKILL` at any moment loses no submitted work.
//! Committed stage dirs re-report as cache hits, the interrupted job is
//! requeued on the next boot, and a fully-cached job completes with zero
//! backend executions and aggregates bitwise-identical to an uninterrupted
//! `repro run` of the same graph (asserted by `tests/jobs_test.rs`).

pub mod api;
pub mod queue;
pub mod store;
pub mod worker;

pub use queue::JobManager;
pub use store::{JobRecord, JobSpec, JobStatus, JobStore, NodeState, NodeStatus};
pub use worker::JobRunner;
