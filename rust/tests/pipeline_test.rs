//! Integration tests over the full coordinator pipeline (gpt-nano, quick
//! profile): prune→retrain→merge→eval with every criterion and mode family.
//!
//! These use few-step training so the suite stays in CI budget; the
//! *qualitative* assertions (ordering, invariants) are the point — exact
//! numbers live in the sweeps.

use perp::config::ExperimentConfig;
use perp::coordinator::reconstruct::{reconstruct, ReconMode};
use perp::coordinator::sweep::ExpContext;
use perp::coordinator::Session;
use perp::peft::Mode;
use perp::pruning::{semistructured, Criterion, Pattern};
use perp::runtime::NativeBackend;

// Backends hold interior-mutable caches (RefCell — not Sync), so each test
// owns one; the dense checkpoint cache on disk keeps pretraining shared.
fn rt() -> NativeBackend {
    NativeBackend::new()
}

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 400;
    c.retrain_steps = 40;
    c.recon_steps = 8;
    c.calib_seqs = 8;
    c.items_per_task = 6;
    c
}

fn ctx(rt: &NativeBackend) -> ExpContext<'_> {
    let dir = std::env::temp_dir().join("perp_itest_cache");
    ExpContext::new(rt, cfg(), dir)
}

#[test]
fn pretraining_reduces_loss() {
    let rt = rt();
    let mut s = Session::new(&rt, cfg(), 3).unwrap();
    s.pretrain(60, 2e-3).unwrap();
    let losses = &s.last_losses;
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first - 0.3,
        "loss should fall during pretraining: {first} -> {last}"
    );
}

#[test]
fn prune_damages_and_subsets_recover() {
    let rt = rt();
    let c = ctx(&rt);
    let (base, _) = c
        .pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.7))
        .unwrap();
    let damaged = {
        let mut s = c.clone_session(&base).unwrap();
        c.evaluate(&mut s, false, None).unwrap().ppl
    };
    let dense = {
        let mut s = c.dense_session(0).unwrap();
        c.evaluate(&mut s, false, None).unwrap().ppl
    };
    assert!(damaged > dense, "pruning must hurt: {dense} vs {damaged}");

    let (bias_cell, _) = c.retrain_tuned(&base, Mode::Biases, 40, false).unwrap();
    assert!(
        bias_cell.ppl < damaged,
        "bias retraining must recover: {damaged} -> {}",
        bias_cell.ppl
    );
}

#[test]
fn all_criteria_hit_target_sparsity() {
    let rt = rt();
    let c = ctx(&rt);
    for crit in [
        Criterion::Magnitude,
        Criterion::MagnitudeGlobal,
        Criterion::Wanda,
        Criterion::SparseGpt,
    ] {
        let (s, _) = c.pruned_session(0, crit, Pattern::Unstructured(0.5)).unwrap();
        let sp = s.masks.sparsity();
        assert!((sp - 0.5).abs() < 0.02, "{}: sparsity {sp}", crit.name());
        // weights agree with masks
        assert!((s.params.weight_sparsity(&s.mm) - sp).abs() < 1e-6);
    }
}

#[test]
fn semistructured_masks_verified_end_to_end() {
    let rt = rt();
    let c = ctx(&rt);
    for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt] {
        let (s, _) = c
            .pruned_session(0, crit, Pattern::SemiStructured { n: 2, m: 4 })
            .unwrap();
        for (name, mask) in &s.masks.masks {
            assert!(
                semistructured::check_nm(mask, 2, 4),
                "{} violated 2:4 on {name}",
                crit.name()
            );
        }
    }
}

#[test]
fn masklora_retrain_preserves_sparsity_through_merge() {
    let rt = rt();
    let c = ctx(&rt);
    let (base, _) = c
        .pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.5))
        .unwrap();
    let sparsity_before = base.masks.sparsity();
    for mode in [Mode::MaskLora, Mode::ScaleLora, Mode::LoraPrune] {
        let mut s = c.clone_session(&base).unwrap();
        s.retrain(mode, 10, 1e-3).unwrap();
        s.merge_adapters().unwrap();
        let after = s.params.weight_sparsity(&s.mm);
        assert!(
            (after - sparsity_before).abs() < 1e-9,
            "{:?} merge changed sparsity {sparsity_before} -> {after}",
            mode
        );
    }
    // plain LoRA destroys it
    let mut s = c.clone_session(&base).unwrap();
    s.retrain(Mode::Lora, 10, 1e-3).unwrap();
    s.merge_adapters().unwrap();
    assert!(s.params.weight_sparsity(&s.mm) < 0.5 * sparsity_before);
}

#[test]
fn wanda_and_sparsegpt_beat_magnitude_after_converged_pruning() {
    // On a converged model at 50%+, calibration-aware criteria should not be
    // (much) worse than magnitude; SparseGPT should be the best of the three.
    let rt = rt();
    let c = ctx(&rt);
    let mut ppls = std::collections::BTreeMap::new();
    for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt] {
        let (mut s, _) = c.pruned_session(0, crit, Pattern::Unstructured(0.6)).unwrap();
        ppls.insert(
            crit.name(),
            c.evaluate(&mut s, false, None).unwrap().ppl,
        );
    }
    assert!(
        ppls["sparsegpt"] <= ppls["magnitude"] * 1.05,
        "{ppls:?}"
    );
}

#[test]
fn reconstruction_improves_pruned_model() {
    let rt = rt();
    let c = ctx(&rt);
    let (base, dense) = c
        .pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.6))
        .unwrap();
    let before = {
        let mut s = c.clone_session(&base).unwrap();
        c.evaluate(&mut s, false, None).unwrap().ppl
    };
    let mut s = c.clone_session(&base).unwrap();
    let target = s.masks.clone();
    let report = reconstruct(&mut s, &target, &dense, ReconMode::MaskLora, 10, 2e-3).unwrap();
    let after = c.evaluate(&mut s, false, None).unwrap().ppl;
    assert!(report.layers.len() == s.mm.prunable.len());
    assert!(
        after < before,
        "reconstruction should improve ppl: {before} -> {after}"
    );
    // sparsity preserved exactly
    assert!((s.params.weight_sparsity(&s.mm) - target.sparsity()).abs() < 1e-9);
}

#[test]
fn full_ft_reconstruction_also_runs() {
    let rt = rt();
    let c = ctx(&rt);
    let (base, dense) = c
        .pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.5))
        .unwrap();
    let mut s = c.clone_session(&base).unwrap();
    let target = s.masks.clone();
    reconstruct(&mut s, &target, &dense, ReconMode::FullFt, 6, 2e-3).unwrap();
    assert!((s.params.weight_sparsity(&s.mm) - target.sparsity()).abs() < 1e-9);
}

#[test]
fn checkpoint_roundtrip_through_session() {
    let rt = rt();
    let c = ctx(&rt);
    let s = c.dense_session(0).unwrap();
    let dir = std::env::temp_dir().join("perp_itest_ckpt");
    let path = dir.join("model.ptns");
    s.save(&path).unwrap();
    let mut s2 = Session::new(&rt, cfg(), 9).unwrap();
    s2.load(&path).unwrap();
    let p1 = s.eval_ppl_test().unwrap().ppl;
    let p2 = s2.eval_ppl_test().unwrap().ppl;
    assert!((p1 - p2).abs() < 1e-6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero_shot_suite_beats_chance_after_training() {
    let rt = rt();
    let c = ctx(&rt);
    let s = c.dense_session(0).unwrap();
    let results = s.eval_tasks().unwrap();
    assert_eq!(results.len(), 7);
    // chance is 50% for 2-option tasks, 25% for 4-option; mean chance ≈ 39%.
    let mean = perp::eval::mean_accuracy(&results);
    assert!(mean > 0.42, "trained model should beat chance: {mean}");
}
