//! `cargo bench --bench table1_subsets_vs_fullft` — regenerates the paper's table1
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("table1");
}
