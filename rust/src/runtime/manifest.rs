//! Artifact manifest: the contract between aot.py and the rust runtime.
//!
//! aot.py records, for every lowered executable, the exact input/output
//! tensor names, shapes and dtypes in call order.  Everything the rust side
//! knows about a model (parameter inventory, groups, prunable set, adapter
//! shapes, trainable sets per mode) comes from here — there is no second
//! source of truth.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?} in manifest"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    fn parse(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req("name").as_str().context("io name")?.to_string(),
            shape: j
                .req("shape")
                .as_arr()
                .context("io shape")?
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect(),
            dtype: DType::parse(j.req("dtype").as_str().context("io dtype")?)?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub group: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static model configuration mirrored from python's ModelConfig.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub d_ff: usize,
    pub use_bias: bool,
    pub norm: String,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub lora_scale: f64,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub calib_rows: usize,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub cfg: ModelCfg,
    pub params: Vec<ParamSpec>,
    pub prunable: Vec<String>,
    /// prunable linear -> capture tap that carries its input (q/k/v share)
    pub taps: BTreeMap<String, String>,
    /// adapter tensors: name (e.g. "h0_attn_q_w::A") -> shape
    pub adapters: Vec<(String, Vec<usize>)>,
    /// retraining mode -> model-parameter names trained under it
    pub trainable: BTreeMap<String, Vec<String>>,
    pub executables: BTreeMap<String, ExecSpec>,
}

impl ModelManifest {
    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn param_shape(&self, name: &str) -> &[usize] {
        &self
            .param(name)
            .unwrap_or_else(|| panic!("unknown param {name:?}"))
            .shape
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .with_context(|| format!("executable {name:?} not in manifest (model {})", self.cfg.name))
    }

    pub fn adapter_shape(&self, name: &str) -> &[usize] {
        &self
            .adapters
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown adapter {name:?}"))
            .1
    }

    /// Total trainable parameter count for a retraining mode (incl adapters
    /// for LoRA modes) — the "% trainable" column of the paper's tables.
    pub fn trainable_count(&self, mode: &str) -> usize {
        let base: usize = self
            .trainable
            .get(mode)
            .map(|names| {
                names
                    .iter()
                    .map(|n| self.param(n).map(|p| p.numel()).unwrap_or(0))
                    .sum()
            })
            .unwrap_or(0);
        let adapters: usize = if is_lora_mode(mode) {
            self.adapters.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
        } else {
            0
        };
        base + adapters
    }
}

pub fn is_lora_mode(mode: &str) -> bool {
    matches!(mode, "lora" | "masklora" | "masklora_std" | "scalelora")
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut models = BTreeMap::new();
        for (name, mj) in j.req("models").as_obj().context("models")? {
            models.insert(name.clone(), parse_model(mj)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .with_context(|| {
                format!(
                    "model {name:?} not in manifest; available: {:?}",
                    self.models.keys().collect::<Vec<_>>()
                )
            })
    }

    pub fn hlo_path(&self, exec: &ExecSpec) -> PathBuf {
        self.dir.join(&exec.file)
    }
}

fn parse_model(j: &Json) -> Result<ModelManifest> {
    let c = j.req("config");
    let cfg = ModelCfg {
        name: c.req("name").as_str().unwrap().to_string(),
        vocab: c.req("vocab").as_usize().unwrap(),
        d_model: c.req("d_model").as_usize().unwrap(),
        n_layers: c.req("n_layers").as_usize().unwrap(),
        n_heads: c.req("n_heads").as_usize().unwrap(),
        seq_len: c.req("seq_len").as_usize().unwrap(),
        d_ff: c.req("d_ff").as_usize().unwrap(),
        use_bias: c.req("use_bias").as_bool().unwrap(),
        norm: c.req("norm").as_str().unwrap().to_string(),
        lora_rank: c.req("lora_rank").as_usize().unwrap(),
        lora_alpha: c.req("lora_alpha").as_f64().unwrap(),
        lora_scale: c.req("lora_scale").as_f64().unwrap(),
        train_batch: c.req("train_batch").as_usize().unwrap(),
        eval_batch: c.req("eval_batch").as_usize().unwrap(),
        calib_rows: c.req("calib_rows").as_usize().unwrap(),
    };
    let params = j
        .req("params")
        .as_arr()
        .context("params")?
        .iter()
        .map(|p| ParamSpec {
            name: p.req("name").as_str().unwrap().to_string(),
            shape: p
                .req("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect(),
            group: p.req("group").as_str().unwrap().to_string(),
        })
        .collect();
    let prunable = j
        .req("prunable")
        .as_arr()
        .context("prunable")?
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect();
    let mut taps = BTreeMap::new();
    for (k, v) in j.req("taps").as_obj().context("taps")? {
        taps.insert(k.clone(), v.as_str().unwrap().to_string());
    }
    let adapters = j
        .req("adapters")
        .as_arr()
        .context("adapters")?
        .iter()
        .map(|a| {
            (
                a.req("name").as_str().unwrap().to_string(),
                a.req("shape")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_usize().unwrap())
                    .collect(),
            )
        })
        .collect();
    let mut trainable = BTreeMap::new();
    for (mode, names) in j.req("trainable").as_obj().context("trainable")? {
        trainable.insert(
            mode.clone(),
            names
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_str().unwrap().to_string())
                .collect(),
        );
    }
    let mut executables = BTreeMap::new();
    for (name, e) in j.req("executables").as_obj().context("executables")? {
        let inputs = e
            .req("inputs")
            .as_arr()
            .unwrap()
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = e
            .req("outputs")
            .as_arr()
            .unwrap()
            .iter()
            .map(IoSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        executables.insert(
            name.clone(),
            ExecSpec {
                name: name.clone(),
                file: e.req("file").as_str().unwrap().to_string(),
                inputs,
                outputs,
            },
        );
    }
    Ok(ModelManifest { cfg, params, prunable, taps, adapters, trainable, executables })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        let nano = m.model("gpt-nano").unwrap();
        assert_eq!(nano.cfg.d_model, 32);
        assert_eq!(nano.prunable.len(), nano.cfg.n_layers * 6);
        assert!(nano.exec("eval_loss").is_ok());
        assert!(nano.exec("train_masklora").is_ok());
        assert!(nano.exec("nope").is_err());
        // every executable file exists on disk
        for e in nano.executables.values() {
            assert!(m.hlo_path(e).exists(), "{e:?}");
        }
    }

    #[test]
    fn trainable_fractions_match_paper_frame() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let mm = m.model("gpt-small").unwrap();
        let total = mm.total_params() as f64;
        let ln = mm.trainable_count("ln") as f64 / total;
        let biases = mm.trainable_count("biases") as f64 / total;
        let lora = mm.trainable_count("masklora") as f64 / total;
        assert!(ln < biases && biases < lora && lora < 0.2, "{ln} {biases} {lora}");
        assert_eq!(mm.trainable_count("full"), mm.total_params());
    }

    #[test]
    fn llama_has_no_bias_group() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let lm = m.model("llama-tiny").unwrap();
        assert_eq!(lm.trainable_count("biases"), 0);
        assert!(!lm.cfg.use_bias);
        assert_eq!(lm.cfg.norm, "rmsnorm");
    }
}
