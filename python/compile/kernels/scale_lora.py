"""ScaleLoRA fused forward/backward Pallas kernels (PERP §3.2).

Multiplicative adapters: ``y = x @ ((B@A) ⊙ (W*M))^T``.  Zeros of the pruned
weight stay zero under the eventual merge ``W <- (BA) ⊙ (W*M)``, so sparsity
is preserved without re-masking.  B and A are ones/sqrt(r)-initialised so that
``BA == 1`` (identity rescale) before retraining.

Tile structure mirrors masked_lora.py: per (bm, bk) weight tile the rank-r
product ``B_tile @ A_tile`` is built in VMEM and Hadamard-combined with the
masked weight tile before the main contraction.

Backward (Z = (BA) ⊙ Weff, Weff = W*M):

    dx  = g @ Z
    dZ  = g^T @ x
    dA  = B^T @ (dZ ⊙ Weff)        dB = (dZ ⊙ Weff) @ A^T
    dW  = dZ ⊙ (BA) ⊙ M            (for the full-FT reconstruction baseline)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MatmulBlocks, cdiv, scratch
from .matmul import mm_nn, mm_nt


def _fused_tile(w, m, a, b):
    ba = jnp.dot(b, a, preferred_element_type=jnp.float32)
    return ba.astype(w.dtype) * (w * m)


def _fwd_kernel(x_ref, w_ref, m_ref, a_ref, b_ref, o_ref, acc_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _fused_tile(w_ref[...], m_ref[...], a_ref[...], b_ref[...])
    acc_ref[...] += jnp.dot(x_ref[...], z.T, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def scale_lora_matmul_fwd_kernel(x, w, mask, a, b):
    """Raw fused forward: x:(n,k), w/mask:(m,k), a:(r,k), b:(m,r) -> (n,m)."""
    n, k = x.shape
    m, _ = w.shape
    r = a.shape[0]
    blk = MatmulBlocks.choose(n, m, k)
    nk = cdiv(k, blk.bk)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, nk=nk),
        grid=(cdiv(n, blk.bn), cdiv(m, blk.bm), nk),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((blk.bm, blk.bk), lambda i, j, l: (j, l)),
            pl.BlockSpec((blk.bm, blk.bk), lambda i, j, l: (j, l)),
            pl.BlockSpec((r, blk.bk), lambda i, j, l: (0, l)),
            pl.BlockSpec((blk.bm, r), lambda i, j, l: (j, 0)),
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(x, w, mask, a, b)


def _bwd_dx_kernel(g_ref, w_ref, m_ref, a_ref, b_ref, o_ref, acc_ref, *, nm):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = _fused_tile(w_ref[...], m_ref[...], a_ref[...], b_ref[...])
    acc_ref[...] += jnp.dot(g_ref[...], z, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nm - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def scale_lora_matmul_bwd_dx_kernel(g, w, mask, a, b):
    n, m = g.shape
    _, k = w.shape
    r = a.shape[0]
    blk = MatmulBlocks.choose(n, k, m)
    nm = cdiv(m, blk.bk)
    return pl.pallas_call(
        functools.partial(_bwd_dx_kernel, nm=nm),
        grid=(cdiv(n, blk.bn), cdiv(k, blk.bm), nm),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((blk.bk, blk.bm), lambda i, j, l: (l, j)),
            pl.BlockSpec((blk.bk, blk.bm), lambda i, j, l: (l, j)),
            pl.BlockSpec((r, blk.bm), lambda i, j, l: (0, j)),
            pl.BlockSpec((blk.bk, r), lambda i, j, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), g.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(g, w, mask, a, b)


@jax.custom_vjp
def scale_lora_matmul(x, w, mask, a, b):
    """y = x @ ((B@A) ⊙ (W*M))^T — fused pallas fwd + bwd."""
    return scale_lora_matmul_fwd_kernel(x, w, mask, a, b)


def _slm_fwd(x, w, mask, a, b):
    return scale_lora_matmul_fwd_kernel(x, w, mask, a, b), (x, w, mask, a, b)


def _slm_bwd(res, g):
    x, w, mask, a, b = res
    weff = w * mask
    dx = scale_lora_matmul_bwd_dx_kernel(g, w, mask, a, b)
    dz = mm_nt(g.T, x.T)
    dzw = dz * weff
    da = mm_nn(b.T, dzw)
    db = mm_nt(dzw, a)
    dw = dz * mm_nn(b, a) * mask
    return dx, dw, None, da, db


scale_lora_matmul.defvjp(_slm_fwd, _slm_bwd)


def scale_lora_init(out_dim: int, in_dim: int, rank: int, dtype=jnp.float32):
    """B = 1/sqrt(r) (out, r), A = 1/sqrt(r) (r, in)  =>  BA == ones."""
    inv = 1.0 / jnp.sqrt(jnp.float32(rank))
    return (
        jnp.full((rank, in_dim), inv, dtype),
        jnp.full((out_dim, rank), inv, dtype),
    )
