//! Global registry of named counters and fixed-bucket histograms.
//!
//! Counters are monotonic `u64`s; histograms bucket `f64` observations
//! into a fixed upper-bound ladder (plus an implicit `+Inf` overflow
//! bucket) and track an exact running sum and count.  Both are lock-free
//! on the hot path: callers hold an `Arc` handle and bump it with relaxed
//! atomics — the registry mutex is only taken on first lookup, snapshot
//! and render.  The [`crate::count!`] macro caches the handle in a
//! per-call-site `OnceLock` so a warm bump is a single `fetch_add`.
//!
//! Naming convention: dotted lower-case paths, e.g. `backend.exec.score`,
//! `spmm.csr`, `plan.cache.hit`, `serve.queue.wait_ms`.  The full catalog
//! lives in the README's Observability section.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default bucket ladder — wide enough to cover milliseconds, counts and
/// fractions without per-metric tuning (an implicit `+Inf` bucket catches
/// the rest).
pub const DEFAULT_BUCKETS: [f64; 14] = [
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0,
];

// ---------------------------------------------------------------------------
// Histogram.
// ---------------------------------------------------------------------------

/// Fixed-bucket histogram: per-bucket counts plus exact sum/count.
pub struct Histogram {
    /// Ascending upper bounds; observations land in the first bucket with
    /// `v <= bound`, or the overflow slot past the end.
    bounds: Vec<f64>,
    /// `bounds.len() + 1` slots (last = `+Inf` overflow).
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    /// Running sum as f64 bits, accumulated with a CAS loop.
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        let mut bounds = bounds.to_vec();
        bounds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        bounds.dedup();
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, total: AtomicU64::new(0), sum_bits: AtomicU64::new(0) }
    }

    pub fn observe(&self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn snapshot(&self) -> HistSnap {
        HistSnap {
            bounds: self.bounds.clone(),
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.count(),
            sum: self.sum(),
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnap {
    pub bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; last slot is `+Inf`.
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistSnap {
    /// Mean of all observations (NaN when empty).
    pub fn mean(&self) -> f64 {
        self.sum / self.count as f64
    }
}

// ---------------------------------------------------------------------------
// Registry.
// ---------------------------------------------------------------------------

/// Named counters + histograms.  One process-wide instance lives behind
/// [`Registry::global`]; subsystems that need isolated counts (e.g. the
/// native backend's per-instance execution ledger) own their own.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    /// set-value metrics (queue depths, running-job counts) — same storage
    /// as counters but rendered as a gauge family and overwritten, never
    /// accumulated
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Handle for a named counter (created zeroed on first use).  Hold the
    /// `Arc` across calls on hot paths — see [`crate::count!`].
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    /// Bump a named counter by `n` (one map lookup; fine off the hot path).
    pub fn add(&self, name: &str, n: u64) {
        self.counter(name).fetch_add(n, Ordering::Relaxed);
    }

    /// Handle for a named gauge (created zeroed on first use).
    pub fn gauge(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    /// Set a named gauge to `v` (last write wins).
    pub fn set_gauge(&self, name: &str, v: u64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    /// Current value of a named gauge (0 if never set).
    pub fn gauge_value(&self, name: &str) -> u64 {
        self.gauge(name).load(Ordering::Relaxed)
    }

    /// Handle for a named histogram with the default bucket ladder.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_with(name, &DEFAULT_BUCKETS)
    }

    /// Handle for a named histogram; `bounds` only applies on first
    /// creation (later callers get the existing ladder).
    pub fn histogram_with(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new(bounds)))
            .clone()
    }

    /// One observation into a named histogram (map lookup per call).
    pub fn observe(&self, name: &str, v: f64) {
        self.histogram(name).observe(v);
    }

    /// Sum of every counter whose name starts with `prefix`.
    pub fn sum_prefixed(&self, prefix: &str) -> u64 {
        let m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        m.iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, v)| v.load(Ordering::Relaxed))
            .sum()
    }

    /// Point-in-time copy of every counter and histogram.
    pub fn snapshot(&self) -> Snapshot {
        let counters = {
            let m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
            m.iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect()
        };
        let hists = {
            let m = self.hists.lock().unwrap_or_else(|e| e.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.snapshot())).collect()
        };
        Snapshot { counters, hists }
    }

    /// Prometheus text exposition of the whole registry under two generic
    /// families: `perp_obs_counter_total{name="..."}` and
    /// `perp_obs_histogram_{bucket,sum,count}{name="..."}` (buckets
    /// cumulative, per convention).
    pub fn render_prometheus(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        if !snap.counters.is_empty() {
            out.push_str("# TYPE perp_obs_counter_total counter\n");
            for (name, v) in &snap.counters {
                out.push_str(&format!(
                    "perp_obs_counter_total{{name=\"{}\"}} {v}\n",
                    metric_escape(name)
                ));
            }
        }
        let gauges: Vec<(String, u64)> = {
            let m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
            m.iter().map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed))).collect()
        };
        if !gauges.is_empty() {
            out.push_str("# TYPE perp_obs_gauge gauge\n");
            for (name, v) in &gauges {
                out.push_str(&format!("perp_obs_gauge{{name=\"{}\"}} {v}\n", metric_escape(name)));
            }
        }
        if !snap.hists.is_empty() {
            out.push_str("# TYPE perp_obs_histogram histogram\n");
            for (name, h) in &snap.hists {
                let name = metric_escape(name);
                let mut cum = 0u64;
                for (i, c) in h.counts.iter().enumerate() {
                    cum += c;
                    let le = match h.bounds.get(i) {
                        Some(b) => format!("{b}"),
                        None => "+Inf".to_string(),
                    };
                    out.push_str(&format!(
                        "perp_obs_histogram_bucket{{name=\"{name}\",le=\"{le}\"}} {cum}\n"
                    ));
                }
                out.push_str(&format!("perp_obs_histogram_sum{{name=\"{name}\"}} {}\n", h.sum));
                out.push_str(&format!(
                    "perp_obs_histogram_count{{name=\"{name}\"}} {}\n",
                    h.count
                ));
            }
        }
        out
    }
}

fn metric_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// ---------------------------------------------------------------------------
// Snapshots + diffs.
// ---------------------------------------------------------------------------

/// Point-in-time copy of a [`Registry`]; subtract two to get the work a
/// region performed ([`Snapshot::since`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, HistSnap>,
}

impl Snapshot {
    /// Counter/histogram deltas accumulated since `earlier` (zero-delta
    /// entries are dropped; counters are monotonic so saturating-sub
    /// guards against mixed-up argument order).
    pub fn since(&self, earlier: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| {
                let before = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(before))
            })
            .filter(|(_, d)| *d > 0)
            .collect();
        let hists = self
            .hists
            .iter()
            .filter_map(|(k, h)| {
                let mut d = h.clone();
                if let Some(b) = earlier.hists.get(k) {
                    if b.bounds == d.bounds {
                        for (dc, bc) in d.counts.iter_mut().zip(&b.counts) {
                            *dc = dc.saturating_sub(*bc);
                        }
                        d.count = d.count.saturating_sub(b.count);
                        d.sum -= b.sum;
                    }
                }
                (d.count > 0).then_some((k.clone(), d))
            })
            .collect();
        Snapshot { counters, hists }
    }
}

// ---------------------------------------------------------------------------
// Percentiles.
// ---------------------------------------------------------------------------

/// Exact percentile over **sorted** samples using the bench-serve
/// convention `sorted[min(floor(len * p), len - 1)]` — shared so every
/// latency report picks the same sample.  Returns NaN on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() as f64 * p) as usize).min(sorted.len() - 1)]
}

/// Bump a named counter on the global registry.  The handle is cached in
/// a per-call-site `OnceLock`, so a warm call is one relaxed `fetch_add`
/// — safe on hot paths.  Requires a string-literal name.
#[macro_export]
macro_rules! count {
    ($name:expr) => {
        $crate::count!($name, 1u64)
    };
    ($name:expr, $n:expr) => {{
        static HANDLE: std::sync::OnceLock<
            std::sync::Arc<std::sync::atomic::AtomicU64>,
        > = std::sync::OnceLock::new();
        HANDLE
            .get_or_init(|| $crate::obs::counters::Registry::global().counter($name))
            .fetch_add($n as u64, std::sync::atomic::Ordering::Relaxed);
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let r = Registry::new();
        r.add("a.x", 2);
        r.add("a.y", 1);
        let h = r.counter("a.x");
        h.fetch_add(3, Ordering::Relaxed);
        let s = r.snapshot();
        assert_eq!(s.counters["a.x"], 5);
        assert_eq!(s.counters["a.y"], 1);
        assert_eq!(r.sum_prefixed("a."), 6);
        assert_eq!(r.sum_prefixed("b."), 0);
    }

    #[test]
    fn snapshot_diff_arithmetic() {
        let r = Registry::new();
        r.add("n.runs", 4);
        r.observe("lat", 0.3);
        let before = r.snapshot();
        r.add("n.runs", 3);
        r.add("n.other", 1);
        r.observe("lat", 7.0);
        r.observe("lat", 0.4);
        let delta = r.snapshot().since(&before);
        assert_eq!(delta.counters["n.runs"], 3);
        assert_eq!(delta.counters["n.other"], 1);
        assert_eq!(delta.counters.len(), 2, "zero deltas must be dropped");
        let lat = &delta.hists["lat"];
        assert_eq!(lat.count, 2);
        assert!((lat.sum - 7.4).abs() < 1e-9);
        assert_eq!(lat.counts.iter().sum::<u64>(), 2);
        // diff of identical snapshots is empty
        let s = r.snapshot();
        assert_eq!(s.since(&s), Snapshot::default());
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let h = Histogram::new(&[1.0, 10.0]);
        for v in [0.5, 1.0, 2.0, 100.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        assert_eq!(s.counts, vec![2, 1, 1]); // <=1, <=10, +Inf
        assert_eq!(s.count, 4);
        assert!((s.sum - 103.5).abs() < 1e-9);
        assert!((s.mean() - 25.875).abs() < 1e-9);
    }

    #[test]
    fn percentile_matches_sort_convention() {
        let lats = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        // the bespoke formula this replaces
        let pct = |p: f64| lats[((lats.len() as f64 * p) as usize).min(lats.len() - 1)];
        for p in [0.0, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&lats, p), pct(p), "p={p}");
        }
        assert_eq!(percentile(&[42.0], 0.5), 42.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn gauges_overwrite_instead_of_accumulating() {
        let r = Registry::new();
        r.set_gauge("jobs.queued", 3);
        r.set_gauge("jobs.queued", 1);
        assert_eq!(r.gauge_value("jobs.queued"), 1);
        assert_eq!(r.gauge_value("jobs.never_set"), 0);
        let text = r.render_prometheus();
        assert!(text.contains("perp_obs_gauge{name=\"jobs.queued\"} 1"), "{text}");
    }

    #[test]
    fn prometheus_rendering() {
        let r = Registry::new();
        r.add("plan.cache.hit", 2);
        r.histogram_with("wait_ms", &[1.0, 5.0]).observe(3.0);
        let text = r.render_prometheus();
        assert!(text.contains("perp_obs_counter_total{name=\"plan.cache.hit\"} 2"));
        assert!(text.contains("perp_obs_histogram_bucket{name=\"wait_ms\",le=\"5\"} 1"));
        assert!(text.contains("perp_obs_histogram_bucket{name=\"wait_ms\",le=\"+Inf\"} 1"));
        assert!(text.contains("perp_obs_histogram_count{name=\"wait_ms\"} 1"));
    }

    #[test]
    fn count_macro_hits_global_registry() {
        let before = Registry::global().snapshot();
        crate::count!("test.macro.bump");
        crate::count!("test.macro.bump", 2);
        let delta = Registry::global().snapshot().since(&before);
        assert_eq!(delta.counters["test.macro.bump"], 3);
    }
}
