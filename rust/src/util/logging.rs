//! Leveled stderr logging + wall-clock scoped timers.
//!
//! `PERP_LOG=debug|info|warn` controls verbosity (default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("PERP_LOG").as_deref() {
        Ok("debug") => 0,
        Ok("warn") => 2,
        _ => 1,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= level()
}

pub fn log(l: Level, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Debug => "DBG",
            Level::Info => "INF",
            Level::Warn => "WRN",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

/// RAII scope timer: logs `<name>: <elapsed>` at info level on drop.
pub struct ScopeTimer {
    name: String,
    start: Instant,
}

impl ScopeTimer {
    pub fn new(name: &str) -> Self {
        ScopeTimer { name: name.to_string(), start: Instant::now() }
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        log(Level::Info, &format!("{}: {:.2}s", self.name, self.elapsed_secs()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn timer_measures() {
        let t = ScopeTimer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
        set_level(Level::Warn); // silence the drop log in test output
    }
}
