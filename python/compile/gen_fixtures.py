"""Generate golden JSON fixtures for the rust NativeBackend tests.

Mirrors model.py's forward/train-step semantics using the pure-jnp oracles in
kernels/ref.py, with jax.value_and_grad as the gradient oracle, and writes
everything (inputs + expected outputs) as JSON under rust/tests/fixtures/.

Deliberately does NOT import compile.model: model.py routes every contraction
through the Pallas kernel package, which only imports on jax versions with
matching pallas APIs — this generator must run anywhere plain jax runs (ref.py
is the stated semantic spec the Pallas kernels are themselves tested against).
The cost is that `param_specs`/`forward` below are a copy of model.py's; when
model.py's architecture changes, update this mirror and regenerate.

    cd python && python -m compile.gen_fixtures --out ../rust/tests/fixtures

Checked-in outputs: model_micro.json (eval/score/adapter-eval/train-step on a
micro GPT), adamw.json, merges.json.  Regenerate whenever ref.py semantics
change.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref

# ---------------------------------------------------------------------------
# Micro config (matches the rust-side test's ModelCfg exactly).
# ---------------------------------------------------------------------------

CFG = dict(
    name="micro",
    vocab=17,
    d_model=8,
    n_layers=2,
    n_heads=2,
    seq_len=6,
    d_ff=32,
    use_bias=True,
    norm="layernorm",
    lora_rank=3,
    lora_alpha=6.0,
    lora_scale=2.0,
    train_batch=2,
    eval_batch=2,
    calib_rows=4,
)


def param_specs(cfg):
    d, ff = cfg["d_model"], cfg["d_ff"]
    ln = cfg["norm"] == "layernorm"
    specs = [
        ("embed_tokens", (cfg["vocab"], d), "embed"),
        ("embed_pos", (cfg["seq_len"], d), "embed"),
    ]
    for i in range(cfg["n_layers"]):
        p = f"h{i}_"
        specs.append((p + "ln1_scale", (d,), "ln"))
        if ln:
            specs.append((p + "ln1_bias", (d,), "ln"))
        for lin in ("attn_q", "attn_k", "attn_v", "attn_o"):
            specs.append((p + lin + "_w", (d, d), "weight"))
            if cfg["use_bias"]:
                specs.append((p + lin + "_b", (d,), "bias"))
        specs.append((p + "ln2_scale", (d,), "ln"))
        if ln:
            specs.append((p + "ln2_bias", (d,), "ln"))
        specs.append((p + "mlp_fc_w", (ff, d), "weight"))
        if cfg["use_bias"]:
            specs.append((p + "mlp_fc_b", (ff,), "bias"))
        specs.append((p + "mlp_proj_w", (d, ff), "weight"))
        if cfg["use_bias"]:
            specs.append((p + "mlp_proj_b", (d,), "bias"))
    specs.append(("final_ln_scale", (d,), "ln"))
    if ln:
        specs.append(("final_ln_bias", (d,), "ln"))
    specs.append(("head_w", (cfg["vocab"], d), "head"))
    return specs


def prunable_names(cfg):
    return [n for n, _, g in param_specs(cfg) if g == "weight"]


# ---------------------------------------------------------------------------
# Forward (mirror of model.py, built on ref.py oracles).
# ---------------------------------------------------------------------------


def _norm(cfg, params, prefix, x2d):
    if cfg["norm"] == "layernorm":
        return ref.layernorm(x2d, params[prefix + "_scale"], params[prefix + "_bias"])
    return ref.rmsnorm(x2d, params[prefix + "_scale"])


def _linear(cfg, params, masks, adapters, mode, name, x2d):
    w = params[name + "_w"]
    m = masks[name + "_w"]
    if mode == "subset" or adapters is None:
        y = ref.masked_matmul(x2d, w, m)
    elif mode == "lora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        y = ref.masked_matmul(x2d, w, m) + cfg["lora_scale"] * ((x2d @ a.T) @ b.T)
    elif mode == "masklora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        y = ref.masked_lora_matmul(x2d, w, m, a, b, cfg["lora_scale"])
    elif mode == "scalelora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        y = ref.scale_lora_matmul(x2d, w, m, a, b)
    else:
        raise ValueError(mode)
    if cfg["use_bias"]:
        y = y + params[name + "_b"][None, :]
    return y


def forward(cfg, params, masks, tokens, adapters=None, mode="subset"):
    bsz, s = tokens.shape
    d = cfg["d_model"]
    h, dh = cfg["n_heads"], cfg["d_model"] // cfg["n_heads"]
    x = params["embed_tokens"][tokens] + params["embed_pos"][None, :s, :]
    for i in range(cfg["n_layers"]):
        p = f"h{i}_"
        hid = _norm(cfg, params, p + "ln1", x.reshape(bsz * s, d))
        q = _linear(cfg, params, masks, adapters, mode, p + "attn_q", hid)
        k = _linear(cfg, params, masks, adapters, mode, p + "attn_k", hid)
        v = _linear(cfg, params, masks, adapters, mode, p + "attn_v", hid)

        def heads(t):
            return t.reshape(bsz, s, h, dh).transpose(0, 2, 1, 3)

        o = ref.attention(heads(q), heads(k), heads(v), True)
        o = o.transpose(0, 2, 1, 3).reshape(bsz * s, d)
        o = _linear(cfg, params, masks, adapters, mode, p + "attn_o", o)
        x = x + o.reshape(bsz, s, d)

        hid = _norm(cfg, params, p + "ln2", x.reshape(bsz * s, d))
        f = _linear(cfg, params, masks, adapters, mode, p + "mlp_fc", hid)
        f = jax.nn.gelu(f)
        f = _linear(cfg, params, masks, adapters, mode, p + "mlp_proj", f)
        x = x + f.reshape(bsz, s, d)

    hid = _norm(cfg, params, "final_ln", x.reshape(bsz * s, d))
    logits = hid @ params["head_w"].T
    return logits.reshape(bsz, s, cfg["vocab"])


def lm_loss_sums(logits, tokens):
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), jnp.float32(tgt.size)


def sequence_scores(logits, tokens, tmask):
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    tm = tmask[:, 1:]
    tok_lp = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(tok_lp * tm, axis=1), jnp.sum(tm, axis=1)


# ---------------------------------------------------------------------------
# Serialisation helpers.
# ---------------------------------------------------------------------------


def arr(x):
    x = np.asarray(x, dtype=np.float64)
    return {"shape": list(x.shape), "data": [float(f"{v:.8e}") for v in x.ravel()]}


def make_state(cfg, seed):
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape, _group in param_specs(cfg):
        if name.endswith("_scale"):
            t = 1.0 + 0.1 * rng.standard_normal(shape)
        elif name.endswith(("_b", "_bias")):
            t = 0.1 * rng.standard_normal(shape)
        else:
            t = 0.3 * rng.standard_normal(shape)
        params[name] = jnp.asarray(t, jnp.float32)
    masks = {}
    for n in prunable_names(cfg):
        shape = params[n].shape
        masks[n] = jnp.asarray(rng.random(shape) > 0.35, jnp.float32)
    adapters = {}
    for n in prunable_names(cfg):
        o, i = params[n].shape
        adapters[n + "::A"] = jnp.asarray(0.2 * rng.standard_normal((cfg["lora_rank"], i)), jnp.float32)
        adapters[n + "::B"] = jnp.asarray(0.2 * rng.standard_normal((o, cfg["lora_rank"])), jnp.float32)
    b, s = cfg["train_batch"], cfg["seq_len"]
    tokens = rng.integers(0, cfg["vocab"], size=(b, s))
    tmask = np.zeros((b, s), np.float32)
    tmask[0, 2:5] = 1.0
    tmask[1, 1:3] = 1.0
    return params, masks, adapters, jnp.asarray(tokens, jnp.int32), jnp.asarray(tmask)


def train_step_fixture(cfg, params, masks, adapters, tokens, mode, trainable_names_, lr, step):
    """One AdamW step over the given leaves; returns expected loss/grads/state."""
    is_lora = mode in ("lora", "masklora", "masklora_std", "scalelora")
    leaf_names = list(trainable_names_)
    if is_lora:
        leaf_names += sorted(adapters.keys())

    def loss_fn(leaves):
        p = dict(params)
        ad = dict(adapters) if is_lora else None
        for k, v in leaves.items():
            if "::" in k:
                ad[k] = v
            else:
                p[k] = v
        graph_mode = "masklora" if mode == "masklora_std" else mode
        logits = forward(cfg, p, masks, tokens, adapters=ad, mode=graph_mode)
        s, c = lm_loss_sums(logits, tokens)
        return s / c

    leaves = {}
    for k in leaf_names:
        leaves[k] = adapters[k] if "::" in k else params[k]
    loss, grads = jax.value_and_grad(loss_fn)(leaves)
    out = {"mode": mode, "lr": lr, "step": step, "loss": float(loss), "leaves": {}}
    for k in leaf_names:
        p0 = leaves[k]
        g = grads[k]
        m0 = jnp.zeros_like(p0)
        v0 = jnp.zeros_like(p0)
        p2, m2, v2 = ref.adamw(p0, g, m0, v0, step, lr)
        out["leaves"][k] = {
            "grad": arr(g),
            "o": arr(p2),
            "om": arr(m2),
            "ov": arr(v2),
        }
    return out


def model_fixture(out_dir):
    cfg = CFG
    params, masks, adapters, tokens, tmask = make_state(cfg, seed=20260728)

    logits = forward(cfg, params, masks, tokens, mode="subset")
    loss_sum, count = lm_loss_sums(logits, tokens)
    scores, counts = sequence_scores(logits, tokens, tmask)

    logits_lora = forward(cfg, params, masks, tokens, adapters=adapters, mode="lora")
    lora_sum, _ = lm_loss_sums(logits_lora, tokens)
    lscores, lcounts = sequence_scores(logits_lora, tokens, tmask)

    biases = [n for n, _, g in param_specs(cfg) if g == "bias"]
    bias_ln = [n for n, _, g in param_specs(cfg) if g in ("bias", "ln")]

    fixture = {
        "cfg": cfg,
        "params": {k: arr(v) for k, v in params.items()},
        "masks": {k: arr(v) for k, v in masks.items()},
        "adapters": {k: arr(v) for k, v in adapters.items()},
        "tokens": [int(t) for t in np.asarray(tokens).ravel()],
        "tmask": arr(tmask),
        "expected": {
            "loss_sum": float(loss_sum),
            "count": float(count),
            "scores": [float(x) for x in scores],
            "counts": [float(x) for x in counts],
            "lora_loss_sum": float(lora_sum),
            "lora_scores": [float(x) for x in lscores],
            "lora_counts": [float(x) for x in lcounts],
            "train_biases": train_step_fixture(
                cfg, params, masks, adapters, tokens, "subset", biases, 1e-3, 1
            ),
            "train_masklora": train_step_fixture(
                cfg, params, masks, adapters, tokens, "masklora", bias_ln, 1e-3, 3
            ),
            "train_scalelora": train_step_fixture(
                cfg, params, masks, adapters, tokens, "scalelora", bias_ln, 1e-3, 2
            ),
        },
    }
    path = os.path.join(out_dir, "model_micro.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"wrote {path} ({os.path.getsize(path) / 1e3:.0f} KB)")


def adamw_fixture(out_dir):
    rng = np.random.default_rng(7)
    p = jnp.asarray(rng.standard_normal((4, 5)), jnp.float32)
    g = jnp.asarray(0.01 * rng.standard_normal((4, 5)), jnp.float32)
    m = jnp.asarray(0.05 * rng.standard_normal((4, 5)), jnp.float32)
    v = jnp.asarray(np.abs(0.002 * rng.standard_normal((4, 5))), jnp.float32)
    cases = []
    for step, lr in [(1, 1e-3), (7, 5e-4), (100, 2e-2)]:
        p2, m2, v2 = ref.adamw(p, g, m, v, step, lr)
        cases.append(
            {"step": step, "lr": lr, "p2": arr(p2), "m2": arr(m2), "v2": arr(v2)}
        )
    fixture = {"p": arr(p), "g": arr(g), "m": arr(m), "v": arr(v), "cases": cases}
    path = os.path.join(out_dir, "adamw.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"wrote {path}")


def merges_fixture(out_dir):
    rng = np.random.default_rng(11)
    out, inp, r = 10, 14, 4
    w = jnp.asarray(0.3 * rng.standard_normal((out, inp)), jnp.float32)
    mask = jnp.asarray(rng.random((out, inp)) > 0.5, jnp.float32)
    a = jnp.asarray(0.2 * rng.standard_normal((r, inp)), jnp.float32)
    b = jnp.asarray(0.2 * rng.standard_normal((out, r)), jnp.float32)
    scale = 2.0
    fixture = {
        "w": arr(w),
        "mask": arr(mask),
        "a": arr(a),
        "b": arr(b),
        "scale": scale,
        "masklora": arr(ref.masklora_merge(w, mask, a, b, scale)),
        "scalelora": arr(ref.scalelora_merge(w, mask, a, b)),
        "lora_prune": arr(ref.lora_prune_merge(w, mask, a, b, scale)),
        "lora": arr(w + scale * (b @ a)),
    }
    path = os.path.join(out_dir, "merges.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../rust/tests/fixtures")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    model_fixture(args.out)
    adamw_fixture(args.out)
    merges_fixture(args.out)


if __name__ == "__main__":
    main()
