//! SynthText: a synthetic, learnable language.
//!
//! Generative process (all deterministic from one seed):
//!
//! 1. A lexicon of `n_words` word strings with Zipfian unigram frequencies.
//! 2. `n_topics` topics; each topic owns a sparse Markov kernel: every word
//!    gets `branch` preferred successors (drawn per topic).  With prob
//!    `coherence` the walk follows a preferred successor (weighted), else it
//!    falls back to the Zipfian unigram draw.
//! 3. A document picks one topic and random-walks for its length; sentences
//!    are delimited with a '.' word, documents with a newline.
//!
//! Why this suffices for PERP: the model must learn (a) the global Zipf
//! marginal, (b) per-topic successor tables, (c) topic persistence across a
//! document.  These are exactly the kinds of distributed features magnitude
//! pruning damages and cheap retraining re-aligns.  Train/val/test documents
//! are disjoint by construction (document index ranges).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_words: usize,
    pub n_topics: usize,
    /// preferred successors per (topic, word)
    pub branch: usize,
    /// probability of following the topic kernel instead of unigram fallback
    pub coherence: f64,
    pub doc_len_words: usize,
    pub n_docs_train: usize,
    pub n_docs_val: usize,
    pub n_docs_test: usize,
    pub seed: u64,
}

impl CorpusConfig {
    /// Scale the corpus to a model's vocab budget: the tokenizer needs room
    /// for all words plus specials, so n_words stays below `vocab`.
    pub fn for_vocab(vocab: usize, seed: u64) -> CorpusConfig {
        let n_words = (vocab * 7 / 8).max(16);
        CorpusConfig {
            // hard enough that the model has no spare capacity: many topics,
            // wide branching, high coherence — every weight ends up carrying
            // successor-table information, which is exactly the regime where
            // magnitude pruning collapses (cf. the paper's OPT observations).
            n_words,
            n_topics: 16,
            branch: 6,
            coherence: 0.92,
            doc_len_words: 256,
            n_docs_train: 600,
            n_docs_val: 40,
            n_docs_test: 60,
            seed,
        }
    }
}

/// A fully generated corpus: word-level documents per split.
pub struct Corpus {
    pub cfg: CorpusConfig,
    /// word id -> surface string (the tokenizer consumes these)
    pub lexicon: Vec<String>,
    /// Zipf weights over the lexicon
    unigram: Vec<f64>,
    /// [topic][word] -> preferred successor ids
    successors: Vec<Vec<Vec<u32>>>,
    /// successor weights (shared shape with successors)
    succ_weights: Vec<f64>,
    pub train: Vec<Vec<u32>>,
    pub val: Vec<Vec<u32>>,
    pub test: Vec<Vec<u32>>,
}

impl Corpus {
    pub fn generate(cfg: CorpusConfig) -> Corpus {
        let mut rng = Rng::new(cfg.seed);
        let lexicon = make_lexicon(cfg.n_words, &mut rng);
        let unigram: Vec<f64> = (0..cfg.n_words)
            .map(|i| 1.0 / ((i + 2) as f64).powf(1.1))
            .collect();
        // per-topic successor tables
        let mut successors = Vec::with_capacity(cfg.n_topics);
        for _ in 0..cfg.n_topics {
            let mut table = Vec::with_capacity(cfg.n_words);
            for _ in 0..cfg.n_words {
                let succ: Vec<u32> = (0..cfg.branch)
                    .map(|_| rng.weighted(&unigram) as u32)
                    .collect();
                table.push(succ);
            }
            successors.push(table);
        }
        let succ_weights: Vec<f64> = (0..cfg.branch).map(|i| 1.0 / (i + 1) as f64).collect();

        let mut c = Corpus {
            cfg,
            lexicon,
            unigram,
            successors,
            succ_weights,
            train: vec![],
            val: vec![],
            test: vec![],
        };
        let mut gen_rng = Rng::new(c.cfg.seed ^ 0xD0C5);
        c.train = c.gen_docs(c.cfg.n_docs_train, &mut gen_rng);
        c.val = c.gen_docs(c.cfg.n_docs_val, &mut gen_rng);
        c.test = c.gen_docs(c.cfg.n_docs_test, &mut gen_rng);
        c
    }

    fn gen_docs(&self, n: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
        (0..n).map(|_| self.gen_doc(self.cfg.doc_len_words, rng)).collect()
    }

    /// Generate one document as word ids under a random topic.
    pub fn gen_doc(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let topic = rng.below(self.cfg.n_topics as u64) as usize;
        self.gen_doc_with_topic(len, topic, rng)
    }

    pub fn gen_doc_with_topic(&self, len: usize, topic: usize, rng: &mut Rng) -> Vec<u32> {
        let mut doc = Vec::with_capacity(len);
        let mut cur = rng.weighted(&self.unigram) as u32;
        doc.push(cur);
        for _ in 1..len {
            cur = self.next_word(topic, cur, rng);
            doc.push(cur);
        }
        doc
    }

    pub fn next_word(&self, topic: usize, cur: u32, rng: &mut Rng) -> u32 {
        if rng.f64() < self.cfg.coherence {
            let succ = &self.successors[topic][cur as usize];
            succ[rng.weighted(&self.succ_weights)]
        } else {
            rng.weighted(&self.unigram) as u32
        }
    }

    pub fn n_topics(&self) -> usize {
        self.cfg.n_topics
    }

    /// Render a document's surface text (what the tokenizer consumes).
    pub fn render(&self, doc: &[u32]) -> String {
        let words: Vec<&str> = doc.iter().map(|&w| self.lexicon[w as usize].as_str()).collect();
        words.join(" ")
    }

    /// Analytical entropy bound: with coherence c and branch k the
    /// conditional distribution mixes a k-support kernel with the unigram;
    /// a fitted model should land well below the unigram entropy.
    pub fn unigram_entropy(&self) -> f64 {
        let z: f64 = self.unigram.iter().sum();
        -self
            .unigram
            .iter()
            .map(|w| {
                let p = w / z;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

fn make_lexicon(n: usize, rng: &mut Rng) -> Vec<String> {
    let consonants = b"bcdfghjklmnprstvwz";
    let vowels = b"aeiou";
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let syllables = 1 + rng.below(3) as usize;
        let mut w = String::new();
        for _ in 0..syllables {
            w.push(consonants[rng.below(consonants.len() as u64) as usize] as char);
            w.push(vowels[rng.below(vowels.len() as u64) as usize] as char);
        }
        if seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Corpus {
        Corpus::generate(CorpusConfig {
            n_words: 64,
            n_topics: 4,
            branch: 3,
            coherence: 0.9,
            doc_len_words: 100,
            n_docs_train: 20,
            n_docs_val: 4,
            n_docs_test: 4,
            seed: 7,
        })
    }

    #[test]
    fn deterministic_generation() {
        let a = small();
        let b = small();
        assert_eq!(a.train, b.train);
        assert_eq!(a.lexicon, b.lexicon);
    }

    #[test]
    fn splits_have_requested_sizes() {
        let c = small();
        assert_eq!(c.train.len(), 20);
        assert_eq!(c.val.len(), 4);
        assert_eq!(c.test.len(), 4);
        assert!(c.train.iter().all(|d| d.len() == 100));
    }

    #[test]
    fn words_in_range_and_zipf_head_heavy() {
        let c = small();
        let mut counts = vec![0usize; c.cfg.n_words];
        for d in &c.train {
            for &w in d {
                assert!((w as usize) < c.cfg.n_words);
                counts[w as usize] += 1;
            }
        }
        let head: usize = counts[..8].iter().sum();
        let tail: usize = counts[32..].iter().sum();
        assert!(head > tail, "zipf head {head} should outweigh tail {tail}");
    }

    #[test]
    fn markov_structure_is_learnable() {
        // the empirical bigram conditional entropy must be well below the
        // unigram entropy — that gap is what a trained model exploits.
        let c = small();
        let v = c.cfg.n_words;
        let mut big = vec![0f64; v * v];
        let mut uni = vec![0f64; v];
        for d in &c.train {
            for w in d.windows(2) {
                big[w[0] as usize * v + w[1] as usize] += 1.0;
                uni[w[0] as usize] += 1.0;
            }
        }
        let mut h_cond = 0.0;
        let total: f64 = uni.iter().sum();
        for a in 0..v {
            if uni[a] == 0.0 {
                continue;
            }
            let mut h = 0.0;
            for b in 0..v {
                let c2 = big[a * v + b];
                if c2 > 0.0 {
                    let p = c2 / uni[a];
                    h -= p * p.ln();
                }
            }
            h_cond += uni[a] / total * h;
        }
        let h_uni = c.unigram_entropy();
        assert!(
            h_cond < 0.75 * h_uni,
            "conditional entropy {h_cond:.2} vs unigram {h_uni:.2}"
        );
    }

    #[test]
    fn render_is_textual() {
        let c = small();
        let text = c.render(&c.train[0][..10]);
        assert!(text.split(' ').count() == 10);
        assert!(text.chars().all(|ch| ch.is_ascii_lowercase() || ch == ' '));
    }

    #[test]
    fn lexicon_unique() {
        let c = small();
        let set: std::collections::HashSet<_> = c.lexicon.iter().collect();
        assert_eq!(set.len(), c.lexicon.len());
    }
}
