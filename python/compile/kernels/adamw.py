"""Fused AdamW update Pallas kernel.

PERP's efficiency argument hinges on optimizer-state footprint: AdamW keeps
two f32 buffers per trainable parameter, so shrinking the trainable set from
100% to 0.01-1% collapses memory.  The update itself is a pure elementwise
map — a single fused VPU pass over (p, g, m, v) — which this kernel expresses
blocked over a flattened 1-D view.

``step`` and ``lr`` are traced scalars shipped as (1,1) blocks broadcast to
every grid cell (scalar-prefetch is TPU-Mosaic-only; this form interprets
everywhere and lowers to the same fused loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv, round_up


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, sc_ref, p2_ref, m2_ref, v2_ref, *,
                  beta1, beta2, eps, wd):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    step = sc_ref[0, 0]
    lr = sc_ref[0, 1]
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - jnp.power(beta1, step))
    vhat = v2 / (1.0 - jnp.power(beta2, step))
    p2_ref[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    m2_ref[...] = m2
    v2_ref[...] = v2


def adamw_update(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0):
    """One fused AdamW step on an arbitrary-shaped tensor.

    step: traced f32 scalar (1-based); lr: traced f32 scalar.
    Returns (p', m', v') with the original shape.
    """
    shape = p.shape
    n = p.size
    block = 4096
    padded = round_up(max(n, 1), block)

    def flat(t):
        f = t.reshape(-1)
        if padded != n:
            f = jnp.pad(f, (0, padded - n))
        return f.reshape(padded // block, block)

    scalars = jnp.stack([step.astype(jnp.float32), lr.astype(jnp.float32)]).reshape(1, 2)
    rows = padded // block
    outs = pl.pallas_call(
        functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps, wd=wd),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((rows, block), p.dtype)] * 3,
        interpret=INTERPRET,
    )(flat(p), flat(g), flat(m), flat(v), scalars)

    def unflat(t):
        return t.reshape(-1)[:n].reshape(shape)

    return unflat(outs[0]), unflat(outs[1]), unflat(outs[2])
