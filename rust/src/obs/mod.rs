//! Observability: hierarchical tracing, counters and histograms — the
//! measurement substrate behind `--trace`, `repro profile`, `plan show
//! --timings` and the serve `/metrics` registry re-emission.
//!
//! PERP's claim is *cheap* retraining, so this repo must be able to show
//! where wall-clock and backend work actually go.  Two pieces:
//!
//! * [`trace`] — RAII spans with thread/worker attribution.  Disabled
//!   (the default) a span is one relaxed atomic load and no allocation;
//!   enabled (`PERP_TRACE=1` / `--trace`) spans land in an in-memory ring
//!   buffer that [`trace::flush`] writes as Chrome trace-event JSON
//!   (loadable in Perfetto / `chrome://tracing`) plus a line-per-span
//!   JSONL twin.  The plan executor, thread-budget shares, native backend
//!   executions and the serve batcher all emit spans, so `--jobs K` worker
//!   occupancy, frontier stalls and per-key run-lock waits become visible
//!   timelines.
//! * [`counters`] — a global [`counters::Registry`] of named monotonic
//!   counters and fixed-bucket histograms with snapshot/diff support.
//!   Always on (a counter bump is one relaxed `fetch_add`); surfaced by
//!   serve `/metrics` in Prometheus text exposition and diffed around
//!   every plan node to annotate reports with per-stage counter deltas.
//!
//! Everything is hand-rolled over std (no tracing/metrics crates), like
//! the rest of [`crate::util`].

pub mod counters;
pub mod trace;
