//! Pruning criteria and mask management (host-side).
//!
//! Implements every pruner the paper touches:
//!
//! * [`magnitude`] — uniform per-layer and global magnitude pruning;
//! * [`semistructured`] — N:M patterns (2:4, 4:8) with deterministic ties;
//! * [`wanda`] — |W|·‖X‖₂ scores from calibration Grams (Sun et al. 2023);
//! * [`sparsegpt`] — the full OBS column-block solver with Cholesky-inverse
//!   Hessians and error compensation (Frantar & Alistarh 2023).
//!
//! All criteria produce a [`MaskSet`]; SparseGPT additionally *updates* the
//! surviving weights.  Pruned entries are represented as exact 0.0 in the
//! mask, and the invariant "merge/update never resurrects a pruned weight"
//! is property-tested throughout.

pub mod magnitude;
pub mod semistructured;
pub mod sparsegpt;
pub mod wanda;

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// Sparsity pattern shared by all criteria.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pattern {
    /// fraction of weights pruned, unstructured
    Unstructured(f64),
    /// keep n of every m consecutive inputs (2:4, 4:8)
    SemiStructured { n: usize, m: usize },
}

impl Pattern {
    pub fn parse(s: &str) -> Result<Pattern, String> {
        if let Some((a, b)) = s.split_once(':') {
            let n = a.parse().map_err(|_| format!("bad pattern {s:?}"))?;
            let m = b.parse().map_err(|_| format!("bad pattern {s:?}"))?;
            return Ok(Pattern::SemiStructured { n, m });
        }
        let f: f64 = s.parse().map_err(|_| format!("bad sparsity {s:?}"))?;
        // accept both 0.5 and 50 (percent)
        let f = if f > 1.0 { f / 100.0 } else { f };
        Ok(Pattern::Unstructured(f))
    }

    /// Nominal fraction of weights removed.
    pub fn nominal_sparsity(&self) -> f64 {
        match self {
            Pattern::Unstructured(f) => *f,
            Pattern::SemiStructured { n, m } => 1.0 - *n as f64 / *m as f64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Pattern::Unstructured(f) => format!("{:.0}%", f * 100.0),
            Pattern::SemiStructured { n, m } => format!("{n}:{m}"),
        }
    }
}

/// Pruning criterion selector (CLI / experiment configs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    Magnitude,
    MagnitudeGlobal,
    Wanda,
    SparseGpt,
}

impl Criterion {
    pub fn parse(s: &str) -> Result<Criterion, String> {
        match s {
            "magnitude" => Ok(Criterion::Magnitude),
            "magnitude-global" => Ok(Criterion::MagnitudeGlobal),
            "wanda" => Ok(Criterion::Wanda),
            "sparsegpt" => Ok(Criterion::SparseGpt),
            other => Err(format!("unknown criterion {other:?}")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Criterion::Magnitude => "magnitude",
            Criterion::MagnitudeGlobal => "magnitude-global",
            Criterion::Wanda => "wanda",
            Criterion::SparseGpt => "sparsegpt",
        }
    }
    /// Does this criterion need calibration Grams?
    pub fn needs_calibration(&self) -> bool {
        matches!(self, Criterion::Wanda | Criterion::SparseGpt)
    }
}

/// Binary masks (0.0 / 1.0 tensors) for every prunable linear.
#[derive(Debug, Clone, Default)]
pub struct MaskSet {
    pub masks: BTreeMap<String, Tensor>,
}

impl MaskSet {
    pub fn dense(prunable: &[String], shapes: impl Fn(&str) -> Vec<usize>) -> MaskSet {
        MaskSet {
            masks: prunable
                .iter()
                .map(|n| (n.clone(), Tensor::ones(&shapes(n))))
                .collect(),
        }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.masks
            .get(name)
            .unwrap_or_else(|| panic!("no mask for {name:?}"))
    }

    pub fn set(&mut self, name: &str, mask: Tensor) {
        debug_assert!(
            mask.data().iter().all(|&x| x == 0.0 || x == 1.0),
            "mask for {name:?} must be binary"
        );
        self.masks.insert(name.to_string(), mask);
    }

    /// Achieved sparsity across all masks.
    pub fn sparsity(&self) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for m in self.masks.values() {
            zeros += m.count(|x| x == 0.0);
            total += m.numel();
        }
        zeros as f64 / total.max(1) as f64
    }

    pub fn per_layer_sparsity(&self) -> Vec<(String, f64)> {
        self.masks
            .iter()
            .map(|(n, m)| (n.clone(), m.zero_fraction()))
            .collect()
    }
}

/// Exact-k smallest selection over raw (non-negative) values: 0.0 marks the
/// k smallest, ties broken by ascending index.
pub fn mask_smallest_k_by(values: &[f32], k: usize) -> Vec<f32> {
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        values[a as usize]
            .partial_cmp(&values[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![1.0f32; values.len()];
    for &i in idx.iter().take(k.min(values.len())) {
        mask[i as usize] = 0.0;
    }
    mask
}

/// Exact-k smallest selection threshold over |values|: returns a binary mask
/// keeping the (len - k) largest |values|; ties broken by ascending index
/// (matches ref.magnitude_mask's stable argsort).
pub fn mask_smallest_k(values: &[f32], k: usize) -> Vec<f32> {
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        values[a as usize]
            .abs()
            .partial_cmp(&values[b as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mask = vec![1.0f32; values.len()];
    for &i in idx.iter().take(k.min(values.len())) {
        mask[i as usize] = 0.0;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pattern_parsing() {
        assert_eq!(Pattern::parse("0.5").unwrap(), Pattern::Unstructured(0.5));
        assert_eq!(Pattern::parse("50").unwrap(), Pattern::Unstructured(0.5));
        assert_eq!(
            Pattern::parse("2:4").unwrap(),
            Pattern::SemiStructured { n: 2, m: 4 }
        );
        assert!(Pattern::parse("x").is_err());
        assert_eq!(Pattern::SemiStructured { n: 2, m: 4 }.nominal_sparsity(), 0.5);
        assert_eq!(Pattern::Unstructured(0.7).label(), "70%");
        assert_eq!(Pattern::SemiStructured { n: 4, m: 8 }.label(), "4:8");
    }

    #[test]
    fn criterion_parsing() {
        for c in ["magnitude", "magnitude-global", "wanda", "sparsegpt"] {
            assert_eq!(Criterion::parse(c).unwrap().name(), c);
        }
        assert!(Criterion::parse("xx").is_err());
        assert!(Criterion::Wanda.needs_calibration());
        assert!(!Criterion::Magnitude.needs_calibration());
    }

    #[test]
    fn mask_smallest_k_exact() {
        let v = [3.0, -1.0, 0.5, -2.0];
        assert_eq!(mask_smallest_k(&v, 2), vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(mask_smallest_k(&v, 0), vec![1.0; 4]);
        assert_eq!(mask_smallest_k(&v, 4), vec![0.0; 4]);
    }

    #[test]
    fn mask_smallest_k_ties_by_index() {
        let v = [1.0, 1.0, 1.0, 1.0];
        assert_eq!(mask_smallest_k(&v, 2), vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn prop_mask_smallest_k_counts() {
        prop::check("mask_k_counts", 50, |g| {
            let n = g.dim(256);
            let k = g.rng.below((n + 1) as u64) as usize;
            let v = g.tensor(n, 1.0);
            let mask = mask_smallest_k(&v, k);
            assert_eq!(mask.iter().filter(|&&x| x == 0.0).count(), k);
            // every kept weight's |v| >= every pruned weight's |v| (up to ties)
            let max_pruned = v
                .iter()
                .zip(&mask)
                .filter(|(_, &m)| m == 0.0)
                .map(|(x, _)| x.abs())
                .fold(0.0f32, f32::max);
            let min_kept = v
                .iter()
                .zip(&mask)
                .filter(|(_, &m)| m == 1.0)
                .map(|(x, _)| x.abs())
                .fold(f32::INFINITY, f32::min);
            assert!(min_kept >= max_pruned || (min_kept - max_pruned).abs() < 1e-6);
        });
    }

    #[test]
    fn maskset_sparsity_accounting() {
        let mut ms = MaskSet::default();
        ms.set("a", Tensor::new(&[2, 2], vec![1., 0., 1., 0.]));
        ms.set("b", Tensor::new(&[2, 2], vec![1., 1., 1., 1.]));
        assert!((ms.sparsity() - 0.25).abs() < 1e-9);
        let per = ms.per_layer_sparsity();
        assert_eq!(per[0].1, 0.5);
        assert_eq!(per[1].1, 0.0);
    }
}
