//! Data pipeline: synthetic corpus ("SynthText"), tokenizer, batcher and
//! calibration sampler.
//!
//! Substitution for the paper's C4 (retraining) + WikiText (perplexity):
//! a probabilistic grammar with Zipfian lexicon and per-topic Markov
//! structure (see [`corpus`]).  The distribution is genuinely learnable —
//! bigram entropy is far below log|V| — so a converged model shows the
//! paper's collapse-and-recover behaviour under pruning, while held-out
//! splits keep perplexity honest.

pub mod batcher;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use batcher::Batcher;
pub use corpus::{Corpus, CorpusConfig};
pub use tokenizer::Tokenizer;
