//! Adapter merge rules (PERP §3.2) and their sparsity invariants.
//!
//! After retraining, the adapters fold back into the dense weight so
//! inference pays zero extra cost.  The whole point of MaskLoRA/ScaleLoRA is
//! that this fold *cannot resurrect pruned weights*; `LoRA` can and does —
//! [`merged_sparsity_loss`] quantifies exactly how much (Table 2's
//! "Mergeable" column is verified programmatically from these functions).

use crate::tensor::{linalg, Tensor};

/// Standard LoRA merge: W + s·BA.  Destroys sparsity (returns dense W).
pub fn lora(w: &Tensor, a: &Tensor, b: &Tensor, scale: f32) -> Tensor {
    let ba = linalg::matmul(b, a);
    w.add(&ba.scale(scale))
}

/// LoRA-Prune: M ⊙ (W + s·BA) — re-prunes the merged update (lossy).
pub fn lora_prune(w: &Tensor, mask: &Tensor, a: &Tensor, b: &Tensor, scale: f32) -> Tensor {
    lora(w, a, b, scale).hadamard(mask)
}

/// MaskLoRA: W·M + M ⊙ (s·BA) — exact, sparsity preserving.
pub fn masklora(w: &Tensor, mask: &Tensor, a: &Tensor, b: &Tensor, scale: f32) -> Tensor {
    let ba = linalg::matmul(b, a);
    w.hadamard(mask).add(&ba.scale(scale).hadamard(mask))
}

/// ScaleLoRA: (BA) ⊙ (W·M) — exact, sparsity preserving.
pub fn scalelora(w: &Tensor, mask: &Tensor, a: &Tensor, b: &Tensor) -> Tensor {
    let ba = linalg::matmul(b, a);
    ba.hadamard(&w.hadamard(mask))
}

/// Does `merged` respect the mask's zero pattern exactly?
pub fn preserves_sparsity(merged: &Tensor, mask: &Tensor) -> bool {
    merged
        .data()
        .iter()
        .zip(mask.data())
        .all(|(&w, &m)| m != 0.0 || w == 0.0)
}

/// ‖forward(adapters) − forward(merged)‖∞ on a probe batch: zero for exact
/// merges, positive for LoRA-Prune (the paper's "noticeable increase in
/// perplexity" has this as its mechanism).
pub fn merge_forward_gap(
    x: &Tensor,
    w: &Tensor,
    mask: &Tensor,
    a: &Tensor,
    b: &Tensor,
    scale: f32,
    merged: &Tensor,
) -> f32 {
    // adapter forward: x @ (W*M)ᵀ + s · (x Aᵀ) Bᵀ   (standard LoRA forward)
    let base = linalg::matmul_nt(x, &w.hadamard(mask));
    let xa = linalg::matmul_nt(x, a);
    let lora_path = linalg::matmul_nt(&xa, b).scale(scale);
    let y_adapter = base.add(&lora_path);
    let y_merged = linalg::matmul_nt(x, merged);
    y_adapter.sub(&y_merged).max_abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    struct Setup {
        x: Tensor,
        w: Tensor,
        mask: Tensor,
        a: Tensor,
        b: Tensor,
    }

    fn setup(rng: &mut Rng, rows: usize, cols: usize, r: usize, sp: f32) -> Setup {
        let w = Tensor::randn(&[rows, cols], 1.0, rng);
        let mask = Tensor::new(
            &[rows, cols],
            (0..rows * cols)
                .map(|_| if rng.f32() < sp { 0.0 } else { 1.0 })
                .collect(),
        );
        Setup {
            x: Tensor::randn(&[6, cols], 1.0, rng),
            w,
            mask,
            a: Tensor::randn(&[r, cols], 0.3, rng),
            b: Tensor::randn(&[rows, r], 0.3, rng),
        }
    }

    #[test]
    fn prop_sparsity_preservation_matrix() {
        prop::check("merge_sparsity", 25, |g| {
            let (rows, cols, sp) = (g.dim(12).max(2), g.dim(24).max(2), g.sparsity());
            let s = setup(&mut g.rng, rows, cols, 4, sp);
            let ml = masklora(&s.w, &s.mask, &s.a, &s.b, 2.0);
            let sl = scalelora(&s.w, &s.mask, &s.a, &s.b);
            let lp = lora_prune(&s.w, &s.mask, &s.a, &s.b, 2.0);
            assert!(preserves_sparsity(&ml, &s.mask));
            assert!(preserves_sparsity(&sl, &s.mask));
            assert!(preserves_sparsity(&lp, &s.mask));
        });
    }

    #[test]
    fn plain_lora_breaks_sparsity() {
        let mut rng = Rng::new(1);
        let s = setup(&mut rng, 8, 16, 4, 0.5);
        let merged = lora(&s.w.hadamard(&s.mask), &s.a, &s.b, 2.0);
        assert!(!preserves_sparsity(&merged, &s.mask));
    }

    #[test]
    fn lora_merge_is_exact_for_dense() {
        // no pruning: LoRA merge must match its own forward exactly
        let mut rng = Rng::new(2);
        let s = setup(&mut rng, 8, 16, 4, 0.0);
        let merged = lora(&s.w, &s.a, &s.b, 2.0);
        let gap = merge_forward_gap(&s.x, &s.w, &s.mask, &s.a, &s.b, 2.0, &merged);
        assert!(gap < 1e-4, "{gap}");
    }

    #[test]
    fn lora_prune_merge_is_lossy_under_sparsity() {
        // the paper's LoRA-Prune failure mode: re-pruning BA changes the
        // function the adapters had learned.
        let mut rng = Rng::new(3);
        let s = setup(&mut rng, 8, 16, 4, 0.6);
        let merged = lora_prune(&s.w.hadamard(&s.mask), &s.mask, &s.a, &s.b, 2.0);
        let gap = merge_forward_gap(&s.x, &s.w, &s.mask, &s.a, &s.b, 2.0, &merged);
        assert!(gap > 1e-2, "expected a real gap, got {gap}");
    }

    #[test]
    fn masklora_merge_matches_masked_forward() {
        // MaskLoRA's defining property: merged plain GEMM == masked adapter
        // forward, bit-for-bit up to float assoc.
        let mut rng = Rng::new(4);
        let s = setup(&mut rng, 10, 20, 4, 0.5);
        let merged = masklora(&s.w, &s.mask, &s.a, &s.b, 2.0);
        // masked adapter forward: x @ (W·M + M ⊙ sBA)ᵀ computed indirectly
        let ba = linalg::matmul(&s.b, &s.a).scale(2.0).hadamard(&s.mask);
        let z = s.w.hadamard(&s.mask).add(&ba);
        let y1 = linalg::matmul_nt(&s.x, &z);
        let y2 = linalg::matmul_nt(&s.x, &merged);
        assert!(y1.allclose(&y2, 1e-5, 1e-5));
    }

    #[test]
    fn scalelora_identity_init_is_noop_merge() {
        let mut rng = Rng::new(5);
        let s = setup(&mut rng, 8, 16, 4, 0.5);
        let r = 4;
        let a = Tensor::full(&[r, 16], 1.0 / (r as f32).sqrt());
        let b = Tensor::full(&[8, r], 1.0 / (r as f32).sqrt());
        let merged = scalelora(&s.w, &s.mask, &a, &b);
        assert!(merged.allclose(&s.w.hadamard(&s.mask), 1e-5, 1e-5));
    }
}
