//! Serving executables: KV-cache `prefill` and single-token `decode_step`.
//!
//! `prefill` runs the ordinary padded forward pass over up to
//! `cfg.serve_slots` prompts, then extracts each layer's K/V head planes
//! from the tape together with the logits at every stream's last valid
//! prompt position.  `decode_step` advances the active streams by exactly
//! one token: it embeds the freshly sampled token at its stream position,
//! runs the per-layer linears over the *compacted* active rows (so a
//! batch=1 stream pays batch=1 cost), attends each stream's single query
//! against its cache rows plus the new K/V, and emits the next-token
//! logits together with the new K/V rows.  The server owns the cache
//! tensors and writes those rows in place — the backend stays stateless.
//!
//! Every arithmetic loop mirrors the full forward pass' accumulation order
//! (`graph::forward` / `ops::attention_fwd`), so greedy KV decoding is
//! bit-identical to re-running the growing context through `forward` —
//! pinned by `tests/decode_parity.rs` on dense and 50%-sparse gpt-nano.

use std::collections::BTreeMap;

use anyhow::Result;
use rayon::prelude::*;

use crate::runtime::manifest::ModelManifest;
use crate::runtime::Outputs;
use crate::tensor::{linalg, pool, Tensor};

use super::graph::{self, GraphIn, ModeKind, SparseView};
use super::ops;

pub(super) fn prefill(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
) -> Result<Outputs> {
    let (params, masks) = super::gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (slots, s, toks) = super::tokens_in(i32s);
    let (_, lens) = i32s["lens"];
    let vocab = mm.cfg.vocab;
    crate::count!("decode.prefills");

    let tape = graph::forward(&gi, toks, slots, s);
    let (full_logits, kv) = tape.into_logits_and_kv();
    let mut lg = pool::zeroed(slots * vocab);
    for (b, &len) in lens.iter().enumerate() {
        let len = (len.max(0) as usize).min(s);
        if len == 0 {
            continue; // idle slot: zero logits, cache plane is garbage
        }
        let src = &full_logits.data()[(b * s + len - 1) * vocab..(b * s + len) * vocab];
        lg[b * vocab..(b + 1) * vocab].copy_from_slice(src);
    }
    pool::recycle(full_logits);

    let mut values = vec![("logits".to_string(), Tensor::new(&[slots, vocab], lg))];
    for (i, (k, v)) in kv.into_iter().enumerate() {
        values.push((format!("k::h{i}"), k));
        values.push((format!("v::h{i}"), v));
    }
    Ok(Outputs { values })
}

pub(super) fn decode_step(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
) -> Result<Outputs> {
    let cfg = &mm.cfg;
    let (nh, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
    let (slots, seq, vocab) = (cfg.serve_slots, cfg.seq_len, cfg.vocab);
    let (params, masks) = super::gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (_, toks) = i32s["tokens"];
    let (_, pos) = i32s["pos"];

    // compact the active streams: row r of every intermediate below belongs
    // to stream `active[r]`, so idle slots cost nothing
    let active: Vec<usize> =
        (0..slots).filter(|&b| pos[b] >= 0 && (pos[b] as usize) < seq).collect();
    crate::count!("decode.steps");
    crate::count!("decode.active_rows", active.len() as u64);

    let mut out_logits = pool::zeroed(slots * vocab);
    let mut knew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * nh * dh)).collect();
    let mut vnew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * nh * dh)).collect();

    if !active.is_empty() {
        let na = active.len();
        // x = E[token] + P[pos], one row per active stream
        let embt = gi.p("embed_tokens");
        let post = gi.p("embed_pos");
        let mut x = pool::zeroed(na * d);
        for (r, &b) in active.iter().enumerate() {
            let tok = (toks[b].max(0) as usize).min(vocab - 1);
            let p = pos[b] as usize;
            let erow = &embt.data()[tok * d..(tok + 1) * d];
            let prow = &post.data()[p * d..(p + 1) * d];
            for j in 0..d {
                x[r * d + j] = erow[j] + prow[j];
            }
        }
        let mut cur = Tensor::new(&[na, d], x);

        for i in 0..cfg.n_layers {
            let pfx = format!("h{i}_");
            let h1 = norm_apply(&gi, &format!("{pfx}ln1"), &cur);
            let q = linear_apply(&gi, &format!("{pfx}attn_q"), &h1);
            let k = linear_apply(&gi, &format!("{pfx}attn_k"), &h1);
            let v = linear_apply(&gi, &format!("{pfx}attn_v"), &h1);
            pool::recycle(h1);
            // the new K/V rows, head-major — both the cache-delta outputs
            // and this step's self-attention contribution
            for (r, &b) in active.iter().enumerate() {
                for hd in 0..nh {
                    let src = r * d + hd * dh;
                    let dst = b * nh * dh + hd * dh;
                    knew[i][dst..dst + dh].copy_from_slice(&k.data()[src..src + dh]);
                    vnew[i][dst..dst + dh].copy_from_slice(&v.data()[src..src + dh]);
                }
            }
            let kc = f32s[format!("k::h{i}").as_str()];
            let vc = f32s[format!("v::h{i}").as_str()];
            let merged = attend(&q, &k, &v, kc, vc, &active, pos, nh, dh, seq);
            pool::recycle(q);
            pool::recycle(k);
            pool::recycle(v);
            let o = linear_apply(&gi, &format!("{pfx}attn_o"), &merged);
            pool::recycle(merged);
            let res_mid = cur.add(&o);
            pool::recycle(cur);
            pool::recycle(o);
            let h2 = norm_apply(&gi, &format!("{pfx}ln2"), &res_mid);
            let fc = linear_apply(&gi, &format!("{pfx}mlp_fc"), &h2);
            pool::recycle(h2);
            let g = ops::gelu(&fc);
            pool::recycle(fc);
            let proj = linear_apply(&gi, &format!("{pfx}mlp_proj"), &g);
            pool::recycle(g);
            cur = res_mid.add(&proj);
            pool::recycle(res_mid);
            pool::recycle(proj);
        }

        let hf = norm_apply(&gi, "final_ln", &cur);
        pool::recycle(cur);
        let logits = linalg::matmul_nt(&hf, gi.p("head_w"));
        pool::recycle(hf);
        for (r, &b) in active.iter().enumerate() {
            out_logits[b * vocab..(b + 1) * vocab]
                .copy_from_slice(&logits.data()[r * vocab..(r + 1) * vocab]);
        }
        pool::recycle(logits);
    }

    let mut values = vec![("logits".to_string(), Tensor::new(&[slots, vocab], out_logits))];
    for (i, (kn, vn)) in knew.into_iter().zip(vnew).enumerate() {
        values.push((format!("knew::h{i}"), Tensor::new(&[slots, nh, dh], kn)));
        values.push((format!("vnew::h{i}"), Tensor::new(&[slots, nh, dh], vn)));
    }
    Ok(Outputs { values })
}

/// Norm forward without keeping the backward cache.
fn norm_apply(gi: &GraphIn, prefix: &str, x: &Tensor) -> Tensor {
    let scale = gi.p(&format!("{prefix}_scale"));
    if gi.mm.cfg.norm == "layernorm" {
        let (y, cache) = ops::layernorm_fwd(x, scale, gi.p(&format!("{prefix}_bias")));
        cache.recycle();
        y
    } else {
        let (y, cache) = ops::rmsnorm_fwd(x, scale);
        cache.recycle();
        y
    }
}

/// Plain masked linear (the decode path always runs merged weights —
/// adapters are folded before serving), routed through the layout seam: at
/// serve-time sparsities the CSR form reads only surviving weights, which
/// is where the decode path's memory-traffic reduction comes from.
fn linear_apply(gi: &GraphIn, base: &str, x: &Tensor) -> Tensor {
    let wname = format!("{base}_w");
    let mut y = graph::masked_fwd(gi, &wname, x);
    if gi.mm.cfg.use_bias {
        ops::add_bias(&mut y, gi.p(&format!("{base}_b")));
    }
    y
}

/// One query per active stream against its cache rows plus the freshly
/// computed K/V at position `pos[b]`.  Mirrors `ops::attention_fwd`'s
/// score/softmax/accumulation order exactly so KV decoding stays
/// bit-identical to the full forward pass.
#[allow(clippy::too_many_arguments)]
fn attend(
    q: &Tensor,
    knew: &Tensor,
    vnew: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    active: &[usize],
    pos: &[i32],
    nh: usize,
    dh: usize,
    seq: usize,
) -> Tensor {
    let na = active.len();
    let d = nh * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = pool::zeroed(na * d);
    let (qd, knd, vnd) = (q.data(), knew.data(), vnew.data());
    let (kcd, vcd) = (kc.data(), vc.data());
    out.par_chunks_mut(d).enumerate().for_each(|(r, orow)| {
        let b = active[r];
        let p = pos[b] as usize; // cached rows 0..p are valid; self at j == p
        for hd in 0..nh {
            let qv = &qd[r * d + hd * dh..r * d + (hd + 1) * dh];
            let newrow = r * d + hd * dh..r * d + (hd + 1) * dh;
            let cbase = b * nh * seq * dh + hd * seq * dh;
            let mut row = vec![0.0f32; p + 1];
            let mut mx = f32::NEG_INFINITY;
            for (j, rj) in row.iter_mut().enumerate() {
                let kj: &[f32] = if j < p {
                    &kcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    &knd[newrow.clone()]
                };
                let dot: f32 = qv.iter().zip(kj).map(|(&a, &c)| a * c).sum();
                *rj = dot * scale;
                mx = mx.max(*rj);
            }
            let mut denom = 0.0f32;
            for rj in row.iter_mut() {
                *rj = (*rj - mx).exp();
                denom += *rj;
            }
            let orow_h = &mut orow[hd * dh..(hd + 1) * dh];
            for (j, &rj) in row.iter().enumerate() {
                let pj = rj / denom;
                let vj: &[f32] = if j < p {
                    &vcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    &vnd[newrow.clone()]
                };
                for (o, &vv) in orow_h.iter_mut().zip(vj) {
                    *o += pj * vv;
                }
            }
        }
    });
    Tensor::new(&[na, d], out)
}
