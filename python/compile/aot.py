"""AOT compile path: lower every L2 graph to HLO text + manifest.json.

Run once per model config (``make artifacts``):

    cd python && python -m compile.aot --out ../artifacts [--configs a,b,c]

Interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, for every executable, the exact input/output tensor
names, shapes and dtypes in call order — the rust runtime binds buffers by
these names and never guesses.

Naming convention for executable inputs:
    p::<param>    model parameter            m::<linear>    sparsity mask
    a::<linear>   LoRA A                     b::<linear>    LoRA B
    om::<leaf>    AdamW first moment         ov::<leaf>     AdamW second moment
    tokens / tmask / x / y0 / w / mask       data tensors
    step / lr                                traced scalars
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import recon as R

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def io_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Lowerer:
    """Collects (function, input specs, io metadata) and writes artifacts."""

    def __init__(self, cfg: M.ModelConfig, out_dir: str):
        self.cfg = cfg
        self.dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.dir, exist_ok=True)
        self.executables = {}
        self.pspecs = M.param_specs(cfg)
        self.shapes = {n: s for n, s, _ in self.pspecs}
        self.prunable = M.prunable_names(cfg)
        self.adapters = M.adapter_specs(cfg)
        self.ad_shapes = dict(self.adapters)

    # ---- input builders -------------------------------------------------

    def param_inputs(self):
        return [io_entry(f"p::{n}", s) for n, s, _ in self.pspecs]

    def mask_inputs(self):
        return [io_entry(f"m::{n}", self.shapes[n]) for n in self.prunable]

    def adapter_inputs(self):
        out = []
        for n, s in self.adapters:
            tag = "a" if n.endswith("::A") else "b"
            out.append(io_entry(f"{tag}::{n[:-3]}", s))
        return out

    def opt_inputs(self, leaf_names):
        ms = [io_entry(f"om::{n}", self._leaf_shape(n)) for n in leaf_names]
        vs = [io_entry(f"ov::{n}", self._leaf_shape(n)) for n in leaf_names]
        return ms + vs

    def _leaf_shape(self, n):
        return self.ad_shapes[n] if n in self.ad_shapes else self.shapes[n]

    # ---- lowering -------------------------------------------------------

    def lower(self, name, fn, inputs, outputs):
        t0 = time.time()
        specs = [
            spec(e["shape"], I32 if e["dtype"] == "i32" else F32) for e in inputs
        ]
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.executables[name] = {
            "file": f"{self.cfg.name}/{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(
            f"  [{self.cfg.name}] {name}: {len(inputs)} in / {len(outputs)} out, "
            f"{len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s",
            flush=True,
        )

    def manifest_entry(self):
        c = self.cfg
        return {
            "config": {
                "name": c.name, "vocab": c.vocab, "d_model": c.d_model,
                "n_layers": c.n_layers, "n_heads": c.n_heads,
                "seq_len": c.seq_len, "d_ff": c.d_ff,
                "use_bias": c.use_bias, "norm": c.norm,
                "lora_rank": c.lora_rank, "lora_alpha": c.lora_alpha,
                "lora_scale": c.lora_scale,
                "train_batch": c.train_batch, "eval_batch": c.eval_batch,
                "calib_rows": c.calib_rows,
            },
            "params": [
                {"name": n, "shape": list(s), "group": g} for n, s, g in self.pspecs
            ],
            "prunable": self.prunable,
            "taps": {n: M.tap_of(n) for n in self.prunable},
            "adapters": [
                {"name": n, "shape": list(s)} for n, s in self.adapters
            ],
            "trainable": {
                mode: M.trainable_names(self.cfg, mode) for mode in M.ALL_MODES
            },
            "executables": self.executables,
        }


# ---------------------------------------------------------------------------
# Per-config lowering plan.
# ---------------------------------------------------------------------------


def unflatten(names, values):
    return dict(zip(names, values))


def lower_config(cfg: M.ModelConfig, out_dir: str, fast: bool = False):
    lw = Lowerer(cfg, out_dir)
    pnames = [n for n, _, _ in lw.pspecs]
    np_, nm = len(pnames), len(lw.prunable)

    # -- eval_loss ---------------------------------------------------------
    def eval_loss(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        tokens = args[np_ + nm]
        logits = M.forward(cfg, params, masks, tokens)
        s, c = M.lm_loss_sums(logits, tokens)
        return s, c

    tok_eval = io_entry("tokens", (cfg.eval_batch, cfg.seq_len), "i32")
    lw.lower(
        "eval_loss", eval_loss,
        lw.param_inputs() + lw.mask_inputs() + [tok_eval],
        [io_entry("loss_sum", ()), io_entry("count", ())],
    )

    # -- score (zero-shot likelihood ranking) -------------------------------
    def score(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        tokens, tmask = args[np_ + nm], args[np_ + nm + 1]
        logits = M.forward(cfg, params, masks, tokens)
        return M.sequence_scores(logits, tokens, tmask)

    lw.lower(
        "score", score,
        lw.param_inputs() + lw.mask_inputs()
        + [tok_eval, io_entry("tmask", (cfg.eval_batch, cfg.seq_len))],
        [io_entry("scores", (cfg.eval_batch,)), io_entry("counts", (cfg.eval_batch,))],
    )

    # -- adapter-active eval (standard LoRA is evaluated unmerged: merging
    # would destroy sparsity — PERP §3.2 / Table 2) -------------------------
    anames_all = [n for n, _ in lw.adapters]

    def eval_loss_lora(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        i = np_ + nm
        adapters = unflatten(anames_all, args[i:i + len(anames_all)])
        tokens = args[i + len(anames_all)]
        logits = M.forward(cfg, params, masks, tokens, adapters=adapters, mode="lora")
        return M.lm_loss_sums(logits, tokens)

    lw.lower(
        "eval_loss_lora", eval_loss_lora,
        lw.param_inputs() + lw.mask_inputs() + lw.adapter_inputs() + [tok_eval],
        [io_entry("loss_sum", ()), io_entry("count", ())],
    )

    def score_lora(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        i = np_ + nm
        adapters = unflatten(anames_all, args[i:i + len(anames_all)])
        tokens, tmask = args[i + len(anames_all)], args[i + len(anames_all) + 1]
        logits = M.forward(cfg, params, masks, tokens, adapters=adapters, mode="lora")
        return M.sequence_scores(logits, tokens, tmask)

    lw.lower(
        "score_lora", score_lora,
        lw.param_inputs() + lw.mask_inputs() + lw.adapter_inputs()
        + [tok_eval, io_entry("tmask", (cfg.eval_batch, cfg.seq_len))],
        [io_entry("scores", (cfg.eval_batch,)), io_entry("counts", (cfg.eval_batch,))],
    )

    # -- train steps ---------------------------------------------------------
    modes = M.ALL_MODES if not fast else ("full", "biases", "masklora")
    for mode in modes:
        is_lora = mode in M.LORA_MODES
        tnames = M.trainable_names(cfg, mode)
        anames = [n for n, _ in lw.adapters] if is_lora else []
        leaf_names = tnames + anames
        step_fn = M.make_train_step(cfg, mode)
        nl = len(leaf_names)

        def train(*args, _mode=mode, _tnames=tnames, _anames=anames,
                  _leaf=leaf_names, _step=step_fn, _nl=nl):
            params = unflatten(pnames, args[:np_])
            masks = unflatten(lw.prunable, args[np_:np_ + nm])
            i = np_ + nm
            adapters = unflatten(_anames, args[i:i + len(_anames)])
            i += len(_anames)
            m = unflatten(_leaf, args[i:i + _nl]); i += _nl
            v = unflatten(_leaf, args[i:i + _nl]); i += _nl
            tokens, step_i, lr = args[i], args[i + 1], args[i + 2]
            trainable = {k: params[k] for k in _tnames}
            frozen = params
            new_leaves, m2, v2, loss = _step(
                trainable, frozen, masks, adapters, m, v, tokens, step_i, lr
            )
            outs = [new_leaves[k] for k in _leaf]
            outs += [m2[k] for k in _leaf]
            outs += [v2[k] for k in _leaf]
            return tuple(outs) + (loss,)

        inputs = (
            lw.param_inputs() + lw.mask_inputs()
            + (lw.adapter_inputs() if is_lora else [])
            + lw.opt_inputs(leaf_names)
            + [io_entry("tokens", (cfg.train_batch, cfg.seq_len), "i32"),
               io_entry("step", ()), io_entry("lr", ())]
        )
        outputs = (
            [io_entry(f"o::{n}", lw._leaf_shape(n)) for n in leaf_names]
            + [io_entry(f"om::{n}", lw._leaf_shape(n)) for n in leaf_names]
            + [io_entry(f"ov::{n}", lw._leaf_shape(n)) for n in leaf_names]
            + [io_entry("loss", ())]
        )
        lw.lower(f"train_{mode}", train, inputs, outputs)

    # -- calibration stats (Wanda / SparseGPT Hessians) ----------------------
    def calib(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        tokens = args[np_ + nm]
        grams = M.calib_stats(cfg, params, masks, tokens)
        return tuple(g for _, g in grams)

    gram_outputs = [
        io_entry(f"gram::{n}", (lw.shapes[n][1], lw.shapes[n][1]))
        for n in M.tap_names(cfg)
    ]
    lw.lower("calib_stats", calib,
             lw.param_inputs() + lw.mask_inputs() + [tok_eval], gram_outputs)

    # -- layer-input capture (reconstruction) --------------------------------
    def capture(*args):
        params = unflatten(pnames, args[:np_])
        masks = unflatten(lw.prunable, args[np_:np_ + nm])
        tokens = args[np_ + nm]
        caps = M.capture_layer_inputs(cfg, params, masks, tokens)
        return tuple(x for _, x in caps)

    ntok = cfg.eval_batch * cfg.seq_len
    cap_outputs = [
        io_entry(f"x::{n}", (ntok, lw.shapes[n][1])) for n in M.tap_names(cfg)
    ]
    lw.lower("capture_inputs", capture,
             lw.param_inputs() + lw.mask_inputs() + [tok_eval], cap_outputs)

    # -- per-shape reconstruction executables ---------------------------------
    shapes = sorted({lw.shapes[n] for n in lw.prunable})
    rows = cfg.calib_rows
    r = cfg.lora_rank
    for (o, i) in shapes:
        tag = f"{o}x{i}"
        lw.lower(
            f"linear_fwd_{tag}", R.linear_fwd,
            [io_entry("x", (rows, i)), io_entry("w", (o, i))],
            [io_entry("y0", (rows, o))],
        )
        step_ml = R.make_recon_step_masklora(cfg.lora_scale)
        lw.lower(
            f"recon_masklora_{tag}", step_ml,
            [io_entry("x", (rows, i)), io_entry("y0", (rows, o)),
             io_entry("w", (o, i)), io_entry("mask", (o, i)),
             io_entry("a", (r, i)), io_entry("b", (o, r)),
             io_entry("om::a", (r, i)), io_entry("ov::a", (r, i)),
             io_entry("om::b", (o, r)), io_entry("ov::b", (o, r)),
             io_entry("step", ()), io_entry("lr", ())],
            [io_entry("o::a", (r, i)), io_entry("o::b", (o, r)),
             io_entry("om::a", (r, i)), io_entry("ov::a", (r, i)),
             io_entry("om::b", (o, r)), io_entry("ov::b", (o, r)),
             io_entry("loss", ())],
        )
        step_full = R.make_recon_step_full()
        lw.lower(
            f"recon_full_{tag}", step_full,
            [io_entry("x", (rows, i)), io_entry("y0", (rows, o)),
             io_entry("w", (o, i)), io_entry("mask", (o, i)),
             io_entry("om::w", (o, i)), io_entry("ov::w", (o, i)),
             io_entry("step", ()), io_entry("lr", ())],
            [io_entry("o::w", (o, i)),
             io_entry("om::w", (o, i)), io_entry("ov::w", (o, i)),
             io_entry("loss", ())],
        )

    return lw.manifest_entry()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="gpt-nano,gpt-tiny,gpt-small,llama-tiny")
    ap.add_argument("--fast", action="store_true",
                    help="lower a reduced executable set (CI smoke)")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"format": 1, "models": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        cfg = M.CONFIGS[name]
        print(f"[aot] lowering {name} ...", flush=True)
        t0 = time.time()
        manifest["models"][name] = lower_config(cfg, args.out, fast=args.fast)
        print(f"[aot] {name} done in {time.time() - t0:.1f}s", flush=True)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path}")


if __name__ == "__main__":
    sys.exit(main())
