"""L1 analytical performance model: VMEM footprint + MXU utilization
estimates from the BlockSpecs (DESIGN.md §Perf).

``interpret=True`` gives CPU-numpy execution, so kernel *wallclock* on this
box is not a TPU proxy; what we can and do optimize is structure: tile sizes
that fit VMEM with double-buffering headroom, MXU-aligned (8×128-multiple)
operand shapes, and arithmetic intensity high enough to clear the HBM
roofline.

Run:  python -m compile.perf_model
"""

from __future__ import annotations

import dataclasses

from .kernels.common import MatmulBlocks, flops_masked_lora

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on contemporary TPUs
MXU_DIM = 128                  # systolic array edge
HBM_GBPS = 1200e9              # HBM bandwidth (v4-class)
MXU_FLOPS = 275e12 / 2         # f32-equivalent peak (bf16 275T / 2)


@dataclasses.dataclass
class KernelEstimate:
    name: str
    shape: str
    blocks: MatmulBlocks
    vmem_bytes: int
    flops: int
    hbm_bytes: int

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    @property
    def mxu_alignment(self) -> float:
        """Fraction of each MXU pass that is real work (edge effects)."""
        def frac(d):
            return d / (-(-d // MXU_DIM) * MXU_DIM)
        return frac(self.blocks.bn) * frac(self.blocks.bm)

    @property
    def roofline_bound(self) -> str:
        # compute-bound iff intensity > peak_flops / bandwidth
        knee = MXU_FLOPS / HBM_GBPS
        return "compute" if self.intensity > knee else "memory"


def masked_lora_estimate(n: int, m: int, k: int, r: int) -> KernelEstimate:
    blk = MatmulBlocks.choose(n, m, k)
    flops = flops_masked_lora(n, m, k, r)
    # HBM traffic: x + w + mask + a + b once, out once (perfect reuse within
    # tiles; masks/weights never re-read thanks to the fused construction)
    hbm = 4 * (n * k + 2 * m * k + r * k + m * r + n * m)
    return KernelEstimate(
        "masked_lora_matmul", f"({n}x{k})·({m}x{k})ᵀ r={r}",
        blk, blk.vmem_bytes(rank=r), flops, hbm,
    )


def report(rows: list[KernelEstimate]) -> str:
    out = [
        f"{'kernel':<22} {'shape':<28} {'tile':<14} {'VMEM':>8} "
        f"{'AI':>7} {'MXU-align':>9} {'bound':>8}"
    ]
    for e in rows:
        tile = f"{e.blocks.bn}x{e.blocks.bm}x{e.blocks.bk}"
        out.append(
            f"{e.name:<22} {e.shape:<28} {tile:<14} "
            f"{e.vmem_bytes / 1024:>6.0f}KB {e.intensity:>7.1f} "
            f"{e.mxu_alignment:>8.0%} {e.roofline_bound:>8}"
        )
    return "\n".join(out)


def paper_scale_rows() -> list[KernelEstimate]:
    """The shapes this kernel would see on the paper's models."""
    rows = []
    # repro fleet
    for d, n in [(32, 128), (64, 512), (128, 1024)]:
        rows.append(masked_lora_estimate(n, d, d, 16))
    # OPT-2.7B (d=2560) and OPT-30B (d=7168) attention + MLP linears,
    # batch 2 x 2048 tokens as in the paper's retraining setup
    for d in (2560, 7168):
        rows.append(masked_lora_estimate(4096, d, d, 16))
        rows.append(masked_lora_estimate(4096, 4 * d, d, 16))
    return rows


def main() -> None:
    rows = paper_scale_rows()
    print(report(rows))
    bad = [e for e in rows if e.vmem_bytes > VMEM_BYTES]
    assert not bad, f"tiles exceed VMEM: {[e.shape for e in bad]}"
    print(
        f"\nall tiles within {VMEM_BYTES >> 20} MiB VMEM; "
        f"knee at AI={MXU_FLOPS / HBM_GBPS:.0f} flops/byte"
    )


if __name__ == "__main__":
    main()
