//! # PERP — Parameter-Efficient Retraining after Pruning
//!
//! Rust + JAX + Pallas reproduction of *PERP: Rethinking the Prune-Retrain
//! Paradigm in the Era of LLMs* (Zimmer et al., 2023).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L1** Pallas kernels and **L2** JAX training graphs live in `python/`
//!   and are AOT-lowered once into `artifacts/*.hlo.txt`.
//! * **L3** (this crate) is the only runtime layer: it owns model weights,
//!   optimizer state, masks and adapters on the host, computes pruning
//!   criteria (magnitude / Wanda / SparseGPT / N:M), schedules retraining
//!   and layer-wise reconstruction, and evaluates perplexity plus a
//!   seven-task zero-shot suite — executing the compiled graphs through the
//!   PJRT CPU client (`runtime`).
//!
//! The environment is fully offline with a fixed crate set, so the usual
//! suspects (serde, clap, criterion, proptest, rand) are re-implemented as
//! small, tested substrates under [`util`].

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod peft;
pub mod pruning;
pub mod runtime;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;
