"""Causal self-attention Pallas kernel.

One grid cell per (batch*head): the full (S, dh) Q/K/V panels are resident in
VMEM (S ≤ 512 at repro scale: 512² f32 scores = 1 MiB, comfortably inside a
TPU core's ~16 MiB VMEM).  This is the "one-tile flash" regime — for longer
sequences the k-block online-softmax extension applies, but the repro configs
never leave one tile, so the simple schedule is the roofline-optimal one (see
DESIGN.md §Perf).

The backward pass recomputes probabilities (flash-style: nothing but q,k,v and
the output gradient are needed) and applies the standard softmax VJP; it is
expressed with jnp on full panels, which XLA fuses into the surrounding HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, scale: float):
    q = q_ref[0]  # (S, dh)
    k = k_ref[0]
    v = v_ref[0]
    s = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        idx = jax.lax.iota(jnp.int32, s)
        mask = idx[:, None] >= idx[None, :]
        scores = jnp.where(mask, scores, _NEG_INF)
    # numerically-stable softmax in VMEM
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def attention_fwd_kernel(q, k, v, causal: bool = True):
    """q,k,v: (B, H, S, dh) -> (B, H, S, dh)."""
    bsz, nh, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    qf = q.reshape(bsz * nh, s, dh)
    kf = k.reshape(bsz * nh, s, dh)
    vf = v.reshape(bsz * nh, s, dh)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal, scale=scale),
        grid=(bsz * nh,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * nh, s, dh), q.dtype),
        interpret=INTERPRET,
    )(qf, kf, vf)
    return out.reshape(bsz, nh, s, dh)


def _probs(q, k, causal):
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        s = q.shape[-2]
        idx = jnp.arange(s)
        scores = jnp.where(idx[:, None] >= idx[None, :], scores, _NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=True):
    """Flash-style causal attention: pallas fwd, recompute bwd."""
    return attention_fwd_kernel(q, k, v, causal)


def _attn_fwd(q, k, v, causal):
    return attention_fwd_kernel(q, k, v, causal), (q, k, v)


def _attn_bwd(causal, res, g):
    q, k, v = res
    dh = q.shape[-1]
    p = _probs(q, k, causal)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, g)
    dp = jnp.einsum("bhqd,bhkd->bhqk", g, v)
    # softmax VJP: ds = p * (dp - sum(dp * p))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = ds / jnp.sqrt(jnp.float32(dh))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k)
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q)
    return dq, dk, dv


attention.defvjp(_attn_fwd, _attn_bwd)
