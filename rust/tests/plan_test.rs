//! Integration tests for `perp::pipeline`: plan files round-trip, the
//! executor's content-addressed cache resumes completed stages with zero
//! backend executions, and the shim path produces metrics identical to the
//! pre-redesign verb sequence.
//!
//! Shares the on-disk dense checkpoint cache with `pipeline_test.rs`
//! (same model / pretrain steps / data seed), so pretraining happens once
//! per machine; each test varies `retrain_steps` slightly so its *plan*
//! stage keys never collide with a concurrently running test.

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::ExpContext;
use perp::peft::Mode;
use perp::pipeline::{parse::parse_plan, Executor, Plan};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{Backend, NativeBackend};

fn rt() -> NativeBackend {
    NativeBackend::new()
}

/// Same pretraining shape as pipeline_test.rs (shared dense checkpoint);
/// `retrain_steps` doubles as a per-test cache namespace.
fn cfg(retrain_steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 400;
    c.retrain_steps = retrain_steps;
    c.recon_steps = 6;
    c.calib_seqs = 8;
    c.items_per_task = 6;
    c.eval_batches = 2;
    c
}

fn cache_dir() -> std::path::PathBuf {
    std::env::temp_dir().join("perp_itest_cache")
}

#[test]
fn plan_file_roundtrips_through_disk() {
    let plan = Plan::new("roundtrip")
        .pretrain()
        .prune(Criterion::Wanda, Pattern::SemiStructured { n: 2, m: 4 })
        .retrain(Mode::MaskLora, Some(25), None)
        .merge()
        .eval()
        .export("results/roundtrip.ptns");
    let dir = std::env::temp_dir().join("perp_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    std::fs::write(&path, plan.to_string_pretty()).unwrap();
    let loaded = Plan::from_file(&path).unwrap();
    assert_eq!(plan, loaded);
    loaded.validate().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inline_spec_equals_builder_plan() {
    let inline = parse_plan("x", "prune(magnitude,0.5)|retrain(masklora,12)|merge|eval(ppl)")
        .unwrap();
    let built = Plan::new("x")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
        .retrain(Mode::MaskLora, Some(12), None)
        .merge()
        .eval_ppl();
    assert_eq!(inline, built);
}

#[test]
fn executor_cache_resume_skips_all_training() {
    let rt = rt();
    let dir = cache_dir();
    let ex = Executor::new(&rt, cfg(11), dir.clone(), 0).quiet(true);
    let export_path = std::env::temp_dir().join("perp_plan_export_test.ptns");
    std::fs::remove_file(&export_path).ok();
    let plan = Plan::new("resume")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
        .retrain(Mode::MaskLora, None, None)
        .merge()
        .eval_ppl()
        .export(export_path.to_str().unwrap());

    // first run may or may not hit stale artifacts; wipe its exact stage
    // dirs so the second run is a guaranteed full compute
    let probe = ex.run(&plan).unwrap();
    for sr in &probe.stages {
        std::fs::remove_dir_all(dir.join("plan").join(&sr.key)).ok();
    }
    std::fs::remove_file(&export_path).ok();

    let first = ex.run(&plan).unwrap();
    assert!(
        first.stages.iter().all(|s| !s.cache_hit),
        "wiped stages must recompute: {first:?}"
    );
    assert!(export_path.is_file(), "export must write its checkpoint");
    let ppl1 = first.last_metrics().expect("eval stage ran").ppl;

    // second run: every stage loads its artifact — zero training steps,
    // zero backend executions.  Export is idempotent: the target file still
    // holds the exact bytes this chain wrote, so it reports a cache hit too
    let execs_before = rt.exec_count();
    let second = ex.run(&plan).unwrap();
    assert_eq!(
        rt.exec_count(),
        execs_before,
        "a resumed plan must not execute any graph"
    );
    for sr in &second.stages {
        assert!(
            sr.cache_hit,
            "stage {} should be cached (export skips identical bytes)",
            sr.label
        );
    }
    let ppl2 = second.last_metrics().expect("cached eval metrics").ppl;
    assert_eq!(ppl1, ppl2, "cached metrics must match the computed run");

    // tampering with the exported file re-runs exactly the export stage and
    // restores the original bytes
    let original = std::fs::read(&export_path).unwrap();
    std::fs::write(&export_path, b"tampered").unwrap();
    let third = ex.run(&plan).unwrap();
    for sr in &third.stages {
        if sr.label.starts_with("export") {
            assert!(!sr.cache_hit, "tampered export target must be rewritten");
        } else {
            assert!(sr.cache_hit, "stage {} should still be cached", sr.label);
        }
    }
    assert_eq!(
        std::fs::read(&export_path).unwrap(),
        original,
        "re-export must restore the exact checkpoint bytes"
    );

    // --force ignores the cache and recomputes everything
    let forced = Executor::new(&rt, cfg(11), dir, 0)
        .quiet(true)
        .force(true)
        .run(&plan)
        .unwrap();
    assert!(forced.stages.iter().all(|s| !s.cache_hit));
    let ppl3 = forced.last_metrics().unwrap().ppl;
    assert!((ppl1 - ppl3).abs() < 1e-9, "forced recompute must agree: {ppl1} vs {ppl3}");
}

#[test]
fn partial_or_staged_stage_dirs_are_never_cache_hits() {
    // stage artifacts are written into `plan/.tmp-*` staging dirs and land
    // via one atomic rename, so a killed run leaves either a complete stage
    // dir or an ignorable staging dir — never a partial dir that later
    // scans as a hit.  Simulate both failure shapes and re-run.
    let rt = rt();
    let dir = cache_dir();
    let ex = Executor::new(&rt, cfg(15), dir.clone(), 0).quiet(true);
    let plan = Plan::new("atomic")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.55))
        .eval_ppl();

    let probe = ex.run(&plan).unwrap();
    for sr in &probe.stages {
        std::fs::remove_dir_all(dir.join("plan").join(&sr.key)).ok();
    }
    let first = ex.run(&plan).unwrap();
    assert!(first.stages.iter().all(|s| !s.cache_hit));
    let ppl1 = first.last_metrics().unwrap().ppl;

    // failure shape 1: a stale staging dir from a "killed" writer.  It must
    // never satisfy a completeness scan (it is not at any key path) and
    // must not disturb a resumed run.
    let stale = dir.join("plan").join(".tmp-deadbeefdeadbeef-0-0");
    std::fs::create_dir_all(&stale).unwrap();
    std::fs::write(stale.join("meta.json"), b"{\"stage\":\"prune\"}").unwrap();

    // failure shape 2: a stage dir stripped of its completion marker —
    // state.ptns survives but meta.json is gone (the pre-atomic-commit
    // hazard).  The stage must recompute, not load the partial artifacts.
    let prune_dir = dir.join("plan").join(&first.stages[1].key);
    std::fs::remove_file(prune_dir.join("meta.json")).unwrap();
    assert!(prune_dir.join("state.ptns").is_file(), "partial artifacts remain");

    let second = ex.run(&plan).unwrap();
    assert!(second.stages[0].cache_hit, "pretrain untouched — still cached");
    assert!(!second.stages[1].cache_hit, "markerless prune dir must recompute");
    assert!(second.stages[2].cache_hit, "eval artifacts untouched — still cached");
    assert!(prune_dir.join("meta.json").is_file(), "recompute restores the marker");
    assert_eq!(second.last_metrics().unwrap().ppl, ppl1);

    // the recompute replaced the partial dir atomically: no staging dirs
    // for THIS plan's keys linger (concurrent tests may hold their own
    // in-flight staging dirs in the shared cache, so scope the scan)
    let tmps: Vec<String> = std::fs::read_dir(dir.join("plan"))
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
        .filter(|n| {
            first.stages.iter().any(|s| n.starts_with(&format!(".tmp-{}", s.key)))
        })
        .collect();
    assert!(tmps.is_empty(), "staging dirs left behind: {tmps:?}");
    assert!(stale.is_dir(), "stale staging dirs are ignored, not adopted");
    std::fs::remove_dir_all(&stale).ok();

    // fully-resumed run stays all-hits after the repair
    let third = ex.run(&plan).unwrap();
    assert!(third.stages.iter().all(|s| s.cache_hit), "{third:?}");
}

#[test]
fn retrain_plan_matches_legacy_sequence() {
    // the pre-redesign path: pruned_session -> retrain_tuned (clone, retrain,
    // merge, eval test ppl)
    let rt = rt();
    let dir = cache_dir();
    let c = ExpContext::new(&rt, cfg(12), dir.clone());
    let (base, _) = c
        .pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.5))
        .unwrap();
    let (cell, _lr) = c.retrain_tuned(&base, Mode::MaskLora, 12, false).unwrap();

    // the plan path the `repro retrain` shim takes
    let ex = Executor::new(&rt, cfg(12), dir, 0).quiet(true);
    let plan = Plan::new("shim-equiv")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
        .retrain(Mode::MaskLora, None, None)
        .merge()
        .eval_ppl();
    let report = ex.run(&plan).unwrap();
    let m = report.last_metrics().expect("eval metrics");
    assert!(
        (m.ppl - cell.ppl).abs() < 1e-9,
        "plan path must reproduce the legacy metrics: {} vs {}",
        m.ppl,
        cell.ppl
    );
    // sparsity survives the whole plan
    assert!((m.sparsity - base.masks.sparsity()).abs() < 1e-9);
}

#[test]
fn reconstruct_resumes_with_correct_targets() {
    // reconstruction targets come from the weights before the prune; when the
    // prune stage is a cache hit, the executor must still reconstruct toward
    // the same targets
    let rt = rt();
    let dir = cache_dir();
    let ex = Executor::new(&rt, cfg(13), dir.clone(), 0).quiet(true);
    let plan = Plan::new("recon-resume")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.6))
        .reconstruct(perp::coordinator::reconstruct::ReconMode::MaskLora, None, None)
        .eval_ppl();

    let probe = ex.run(&plan).unwrap();
    for sr in &probe.stages {
        std::fs::remove_dir_all(dir.join("plan").join(&sr.key)).ok();
    }
    let first = ex.run(&plan).unwrap();
    let ppl1 = first.last_metrics().unwrap().ppl;

    // drop only the reconstruct + eval artifacts: prune resumes from cache,
    // reconstruct recomputes — toward targets snapshotted from the resumed
    // session
    for sr in &first.stages {
        if sr.label.starts_with("reconstruct") || sr.label.starts_with("eval") {
            std::fs::remove_dir_all(dir.join("plan").join(&sr.key)).ok();
        }
    }
    let second = ex.run(&plan).unwrap();
    assert!(second.stages[1].cache_hit, "prune must resume from cache");
    assert!(!second.stages[2].cache_hit, "reconstruct must recompute");
    let ppl2 = second.last_metrics().unwrap().ppl;
    assert!(
        (ppl1 - ppl2).abs() < 1e-9,
        "resumed reconstruction must match the cold run: {ppl1} vs {ppl2}"
    );
}

#[test]
fn lora_mode_evaluates_unmerged_through_plans() {
    let rt = rt();
    let ex = Executor::new(&rt, cfg(14), cache_dir(), 0).quiet(true);
    let plan = Plan::new("lora-unmerged")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
        .retrain(Mode::Lora, Some(5), None)
        .eval_ppl();
    let report = ex.run(&plan).unwrap();
    let m = report.last_metrics().expect("eval metrics");
    assert!(m.ppl.is_finite());
    // weights stay sparse — the adapters carry the dense correction
    assert!(m.sparsity > 0.45, "sparsity {}", m.sparsity);
}
