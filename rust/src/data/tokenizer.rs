//! Word-level tokenizer with a frequency-built vocabulary.
//!
//! The corpus generator emits surface text; this tokenizer builds its vocab
//! from the training split (most-frequent-first), reserving specials:
//!
//! * `<pad>` = 0, `<unk>` = 1, `<bos>` = 2, `<sep>` = 3
//!
//! Words beyond the vocab budget map to `<unk>`.  Encoding/decoding is
//! whitespace-based (the synthetic lexicon contains no punctuation), which
//! keeps the pipeline honest — model vocab ids are *tokenizer* ids, not
//! generator word ids, exactly like a real corpus→tokenizer→model stack.

use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const UNK: i32 = 1;
pub const BOS: i32 = 2;
pub const SEP: i32 = 3;
pub const N_SPECIALS: usize = 4;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: HashMap<String, i32>,
    inverse: Vec<String>,
}

impl Tokenizer {
    /// Build a vocab of at most `vocab_size` entries from training text.
    pub fn train(texts: &[String], vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > N_SPECIALS, "vocab too small");
        let mut counts: HashMap<&str, u64> = HashMap::new();
        for t in texts {
            for w in t.split_whitespace() {
                *counts.entry(w).or_insert(0) += 1;
            }
        }
        let mut by_freq: Vec<(&str, u64)> = counts.into_iter().collect();
        // sort: frequency desc, then lexicographic for determinism
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let mut inverse = vec![
            "<pad>".to_string(),
            "<unk>".to_string(),
            "<bos>".to_string(),
            "<sep>".to_string(),
        ];
        for (w, _) in by_freq.into_iter().take(vocab_size - N_SPECIALS) {
            inverse.push(w.to_string());
        }
        let vocab = inverse
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { vocab, inverse }
    }

    pub fn vocab_size(&self) -> usize {
        self.inverse.len()
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| self.vocab.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    /// Generation stop token.  The synthetic corpus has no dedicated EOS;
    /// `<sep>` (the document-segment separator the model learns to emit
    /// between spans) plays that role for the serving layer.
    pub fn eos(&self) -> i32 {
        SEP
    }

    /// Encode a generation prompt: `<bos>` + text, left-truncated to
    /// `max_len` tokens so the most recent context survives.  Always
    /// returns at least the BOS token.
    pub fn encode_prompt(&self, text: &str, max_len: usize) -> Vec<i32> {
        let max_len = max_len.max(1);
        let mut ids = vec![BOS];
        ids.extend(self.encode(text));
        if ids.len() > max_len {
            ids.drain(..ids.len() - max_len);
        }
        ids
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| {
                self.inverse
                    .get(i as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<bad>")
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn unk_rate(&self, ids: &[i32]) -> f64 {
        ids.iter().filter(|&&i| i == UNK).count() as f64 / ids.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let texts = vec![
            "ba ba ba ce ce du".to_string(),
            "ba ce fu".to_string(),
        ];
        Tokenizer::train(&texts, 6) // 4 specials + 2 words
    }

    #[test]
    fn most_frequent_words_kept() {
        let t = toy();
        assert_eq!(t.vocab_size(), 6);
        // "ba" (4x) and "ce" (3x) survive; "du"/"fu" fall to <unk>
        let ids = t.encode("ba ce du fu");
        assert_eq!(ids[0], 4);
        assert_eq!(ids[1], 5);
        assert_eq!(ids[2], UNK);
        assert_eq!(ids[3], UNK);
    }

    #[test]
    fn roundtrip_known_words() {
        let t = toy();
        let ids = t.encode("ba ce ba");
        assert_eq!(t.decode(&ids), "ba ce ba");
    }

    #[test]
    fn deterministic_tie_break() {
        let texts = vec!["aa bb".to_string()];
        let t1 = Tokenizer::train(&texts, 6);
        let t2 = Tokenizer::train(&texts, 6);
        assert_eq!(t1.encode("aa bb"), t2.encode("aa bb"));
    }

    #[test]
    fn unk_rate_measured() {
        let t = toy();
        let ids = t.encode("ba xx yy");
        assert!((t.unk_rate(&ids) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn prompt_encoding_keeps_recent_context() {
        let t = toy();
        let ids = t.encode_prompt("ba ce ba", 10);
        assert_eq!(ids[0], BOS);
        assert_eq!(ids.len(), 4);
        // left truncation: the newest tokens survive, BOS may be dropped
        let ids = t.encode_prompt("ba ce ba ce", 2);
        assert_eq!(ids.len(), 2);
        assert_eq!(t.decode(&ids), "ba ce");
        // degenerate budget still yields something to prefill
        assert_eq!(t.encode_prompt("", 0), vec![BOS]);
        assert_eq!(t.eos(), SEP);
    }
}
