//! End-to-end smoke of the execution backend: rust-initialised params through
//! the `eval_loss` / `train_biases` graphs on the hermetic native backend.
//!
//! These are the same assertions the PJRT bridge smoke ran — the Backend
//! trait keeps them backend-blind, so they double as the trait's contract
//! tests (caching, shape validation, output naming).

use std::collections::BTreeMap;

use perp::model::{init, ParamStore};
use perp::runtime::{Backend, Feed, NativeBackend};
use perp::tensor::Tensor;
use perp::util::rng::Rng;

fn ones_masks(mm: &perp::runtime::ModelManifest) -> BTreeMap<String, Tensor> {
    mm.prunable
        .iter()
        .map(|n| (n.clone(), Tensor::ones(mm.param_shape(n))))
        .collect()
}

fn feed_params<'a>(
    feed: Feed<'a>,
    ps: &'a ParamStore,
    masks: &'a BTreeMap<String, Tensor>,
) -> Feed<'a> {
    let mut f = feed;
    for (name, t) in ps.map() {
        f = f.owned_key(format!("p::{name}"), t);
    }
    for (name, t) in masks {
        f = f.owned_key(format!("m::{name}"), t);
    }
    f
}

#[test]
fn eval_loss_near_uniform_at_init() {
    let rt = NativeBackend::new();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(0);
    let ps = init::init_params(&mm, &mut rng);
    let masks = ones_masks(&mm);

    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let tokens: Vec<i32> = (0..b * s)
        .map(|_| rng.below(mm.cfg.vocab as u64) as i32)
        .collect();
    let shape = [b, s];
    let feed = feed_params(Feed::new(), &ps, &masks).ints("tokens", &shape, &tokens);
    let out = rt.run("gpt-nano", "eval_loss", &feed).unwrap();
    let loss = out.scalar("loss_sum") / out.scalar("count");
    let uniform = (mm.cfg.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.6,
        "init loss {loss} should be near log(V)={uniform}"
    );
}

#[test]
fn train_biases_step_updates_only_biases() {
    let rt = NativeBackend::new();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(1);
    let ps = init::init_params(&mm, &mut rng);
    let masks = ones_masks(&mm);
    let trainables = mm.trainable.get("biases").unwrap().clone();
    assert!(!trainables.is_empty());

    let b = mm.cfg.train_batch;
    let s = mm.cfg.seq_len;
    let tokens: Vec<i32> = (0..b * s)
        .map(|_| rng.below(mm.cfg.vocab as u64) as i32)
        .collect();
    let shape = [b, s];

    let mut feed = feed_params(Feed::new(), &ps, &masks)
        .ints("tokens", &shape, &tokens)
        .scalar("step", 1.0)
        .scalar("lr", 0.1);
    for n in &trainables {
        feed = feed
            .owned(&format!("om::{n}"), Tensor::zeros(mm.param_shape(n)))
            .owned(&format!("ov::{n}"), Tensor::zeros(mm.param_shape(n)));
    }
    let mut out = rt.run("gpt-nano", "train_biases", &feed).unwrap();
    let loss = out.scalar("loss");
    assert!(loss.is_finite() && loss > 0.0);

    // updated biases differ from the zero init; moments became nonzero
    let updated = out.drain_prefix("o::");
    assert_eq!(updated.len(), trainables.len());
    let mut any_moved = false;
    for (name, t) in &updated {
        assert_eq!(t.shape(), mm.param_shape(name));
        if t.max_abs() > 0.0 {
            any_moved = true;
        }
    }
    assert!(any_moved, "no bias moved after one step");
    // the moment buffers moved too
    let new_m = out.drain_prefix("om::");
    assert_eq!(new_m.len(), trainables.len());
    assert!(new_m.iter().any(|(_, t)| t.max_abs() > 0.0));
}

#[test]
fn executable_cache_prepares_once() {
    let rt = NativeBackend::new();
    rt.prepare("gpt-nano", "eval_loss").unwrap();
    rt.prepare("gpt-nano", "eval_loss").unwrap();
    assert_eq!(rt.compiled_count(), 1);
    rt.prepare("gpt-nano", "score").unwrap();
    assert_eq!(rt.compiled_count(), 2);
    assert_eq!(rt.exec_count(), 0, "prepare must not execute");
}

#[test]
fn feed_shape_mismatch_is_detected() {
    let rt = NativeBackend::new();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let ps = ParamStore::zeros(&mm);
    let masks = ones_masks(&mm);
    let tokens = vec![0i32; 4]; // wrong shape
    let shape = [2usize, 2];
    let feed = feed_params(Feed::new(), &ps, &masks).ints("tokens", &shape, &tokens);
    let err = rt.run("gpt-nano", "eval_loss", &feed);
    assert!(err.is_err());
}

#[test]
fn missing_input_is_reported_by_name() {
    let rt = NativeBackend::new();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let ps = ParamStore::zeros(&mm);
    let masks = ones_masks(&mm);
    // no tokens fed at all
    let feed = feed_params(Feed::new(), &ps, &masks);
    let err = rt.run("gpt-nano", "eval_loss", &feed).unwrap_err();
    assert!(format!("{err:#}").contains("tokens"), "{err:#}");
}

#[test]
fn adapter_feed_round_trips_through_train_masklora() {
    let rt = NativeBackend::new();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(2);
    let ps = init::init_params(&mm, &mut rng);
    let masks = ones_masks(&mm);
    let lora = perp::peft::LoraState::init(&mm, perp::peft::Mode::MaskLora, &mut rng);

    let trainables = mm.trainable.get("masklora").unwrap().clone();
    let leaves: Vec<String> = trainables
        .iter()
        .cloned()
        .chain(mm.adapters.iter().map(|(n, _)| n.clone()))
        .collect();

    let b = mm.cfg.train_batch;
    let s = mm.cfg.seq_len;
    let tokens: Vec<i32> = (0..b * s)
        .map(|_| rng.below(mm.cfg.vocab as u64) as i32)
        .collect();
    let shape = [b, s];
    let mut feed = feed_params(Feed::new(), &ps, &masks)
        .ints("tokens", &shape, &tokens)
        .scalar("step", 1.0)
        .scalar("lr", 1e-3);
    for (name, t) in &lora.tensors {
        let (lin, tag) = perp::coordinator::session::split_adapter_name(name);
        feed = feed.owned_key(format!("{tag}::{lin}"), t);
    }
    let leaf_shape = |n: &str| -> Vec<usize> {
        if n.contains("::") {
            mm.adapter_shape(n).to_vec()
        } else {
            mm.param_shape(n).to_vec()
        }
    };
    for n in &leaves {
        feed = feed
            .owned(&format!("om::{n}"), Tensor::zeros(&leaf_shape(n)))
            .owned(&format!("ov::{n}"), Tensor::zeros(&leaf_shape(n)));
    }
    let mut out = rt.run("gpt-nano", "train_masklora", &feed).unwrap();
    assert!(out.scalar("loss").is_finite());
    let updated = out.drain_prefix("o::");
    assert_eq!(updated.len(), leaves.len());
    // B matrices start at zero; after one step at least one B entry moved
    let moved_b = updated
        .iter()
        .filter(|(n, _)| n.ends_with("::B"))
        .any(|(_, t)| t.max_abs() > 0.0);
    assert!(moved_b, "MaskLoRA B adapters did not move");
}
