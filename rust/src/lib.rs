//! # PERP — Parameter-Efficient Retraining after Pruning
//!
//! Rust + JAX + Pallas reproduction of *PERP: Rethinking the Prune-Retrain
//! Paradigm in the Era of LLMs* (Zimmer et al., 2023).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L1** Pallas kernels and **L2** JAX training graphs live in `python/`
//!   and can be AOT-lowered once into `artifacts/*.hlo.txt` (the optional
//!   `pjrt` path).
//! * **L3** (this crate) is the only runtime layer: it owns model weights,
//!   optimizer state, masks and adapters on the host, computes pruning
//!   criteria (magnitude / Wanda / SparseGPT / N:M), schedules retraining
//!   and layer-wise reconstruction, and evaluates perplexity plus a
//!   seven-task zero-shot suite — executing the named graphs through a
//!   pluggable [`runtime::Backend`]:
//!
//!   * [`runtime::NativeBackend`] (default) — hermetic, pure-rust,
//!     rayon-parallel implementation of every graph; `cargo test` and all
//!     examples run with zero native dependencies.
//!   * `runtime::PjrtBackend` (cargo feature `pjrt`) — the AOT HLO-text
//!     artifacts executed on the PJRT CPU client.
//!
//! The environment is fully offline with a fixed crate set, so the usual
//! suspects (serde, clap, criterion, proptest, rand) are re-implemented as
//! small, tested substrates under [`util`].

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod jobs;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod peft;
pub mod pipeline;
pub mod pruning;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

pub use tensor::Tensor;
