//! Hierarchical spans with thread attribution, flushed as Chrome
//! trace-event JSON (Perfetto / `chrome://tracing`) plus a JSONL twin.
//!
//! Disabled (the default) the whole module costs one relaxed atomic load
//! per span site and performs **no allocation** — the [`crate::span!`]
//! macro checks [`enabled`] before touching its format arguments.
//! Enabled via `PERP_TRACE=1` (or `=path/to/trace.json`) or the CLI
//! `--trace` flag, every [`Span`] records (name, category, thread,
//! nesting depth, start, duration, args) into a bounded in-memory ring
//! buffer; [`flush`] writes the buffer out at process exit.
//!
//! Threads are attributed by a process-local id assigned on first use;
//! worker threads named at spawn (`plan-worker-0`, ...) become named
//! tracks in the Chrome viewer via `thread_name` metadata events.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Ring-buffer capacity; the oldest spans are dropped past this (the
/// drop count is reported in the flushed file's metadata).
const RING_CAP: usize = 1 << 18;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Hot-path gate: a single relaxed load.  Every recording entry point
/// (and the [`crate::span!`] macro) checks this first, so with tracing
/// off no names are formatted and nothing allocates.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

struct State {
    events: VecDeque<SpanEvent>,
    /// tid -> thread name, registered on each thread's first span.
    threads: BTreeMap<u64, String>,
    /// flush target (Chrome JSON path; the JSONL twin derives from it).
    out: Option<PathBuf>,
}

fn state() -> &'static Mutex<State> {
    static STATE: OnceLock<Mutex<State>> = OnceLock::new();
    STATE.get_or_init(|| {
        Mutex::new(State { events: VecDeque::new(), threads: BTreeMap::new(), out: None })
    })
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Turn tracing on/off and set the flush target.  The CLI calls this
/// while parsing common flags: `--trace` (or `PERP_TRACE=1`) targets
/// `<out>/trace.json`, `PERP_TRACE=<path>` overrides the path.
pub fn configure(on: bool, out: Option<PathBuf>) {
    let _ = epoch(); // pin t=0 before any span
    {
        let mut st = state().lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = out {
            st.out = Some(p);
        }
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Resolve the `PERP_TRACE` env knob: `Some(path_override)` nested in an
/// on/off decision.  `""`/`"0"`/`"false"` = off, `"1"`/`"true"` = on with
/// the default path, anything else = on, writing to that path.
pub fn env_request() -> Option<Option<PathBuf>> {
    match std::env::var("PERP_TRACE") {
        Err(_) => None,
        Ok(v) => match v.trim() {
            "" | "0" | "false" => None,
            "1" | "true" => Some(None),
            path => Some(Some(PathBuf::from(path))),
        },
    }
}

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static DEPTH: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

fn register_thread(st: &mut State, tid: u64) {
    st.threads.entry(tid).or_insert_with(|| {
        std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"))
    });
}

/// One completed span (Chrome "X" complete event).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: String,
    pub cat: &'static str,
    pub tid: u64,
    /// Nesting depth on this thread at entry (0 = top level).
    pub depth: u32,
    /// Microseconds since the trace epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    pub args: Vec<(&'static str, String)>,
}

/// RAII span: records itself into the ring buffer on drop.  Construct
/// through [`crate::span!`] (zero-cost when disabled) or [`Span::start`].
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name: String,
    cat: &'static str,
    tid: u64,
    depth: u32,
    start: Instant,
    args: Vec<(&'static str, String)>,
}

impl Span {
    /// The no-op span handed out while tracing is disabled.
    #[inline]
    pub fn off() -> Span {
        Span { inner: None }
    }

    /// Open a span now.  Callers with pre-formatted names can use this
    /// directly; prefer [`crate::span!`] so name formatting is skipped
    /// when tracing is off.
    pub fn start(cat: &'static str, name: impl Into<String>) -> Span {
        if !enabled() {
            return Span::off();
        }
        let tid = TID.with(|t| *t);
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        Span {
            inner: Some(SpanInner {
                name: name.into(),
                cat,
                tid,
                depth,
                start: Instant::now(),
                args: Vec::new(),
            }),
        }
    }

    /// Attach a key/value argument (shown in the trace viewer).  The
    /// value is only formatted when the span is live.
    pub fn arg(mut self, key: &'static str, value: impl std::fmt::Display) -> Span {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, value.to_string()));
        }
        self
    }

    /// Whether this span is actually recording.
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let ep = epoch();
        let ts_us = inner.start.duration_since(ep).as_micros() as u64;
        let dur_us = inner.start.elapsed().as_micros() as u64;
        let ev = SpanEvent {
            name: inner.name,
            cat: inner.cat,
            tid: inner.tid,
            depth: inner.depth,
            ts_us,
            dur_us,
            args: inner.args,
        };
        let mut st = state().lock().unwrap_or_else(|e| e.into_inner());
        register_thread(&mut st, ev.tid);
        if st.events.len() >= RING_CAP {
            st.events.pop_front();
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        st.events.push_back(ev);
    }
}

/// Spans dropped to ring-buffer overflow so far.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Number of spans currently buffered.
pub fn buffered() -> usize {
    state().lock().unwrap_or_else(|e| e.into_inner()).events.len()
}

/// Drain and return all buffered spans (test/introspection hook; flush
/// uses it internally).
pub fn drain() -> Vec<SpanEvent> {
    let mut st = state().lock().unwrap_or_else(|e| e.into_inner());
    st.events.drain(..).collect()
}

fn event_json(ev: &SpanEvent) -> Json {
    let mut args = vec![("depth", Json::Num(ev.depth as f64))];
    for (k, v) in &ev.args {
        args.push((*k, Json::Str(v.clone())));
    }
    Json::obj(vec![
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(ev.tid as f64)),
        ("name", Json::Str(ev.name.clone())),
        ("cat", Json::Str(ev.cat.to_string())),
        ("ts", Json::Num(ev.ts_us as f64)),
        ("dur", Json::Num(ev.dur_us as f64)),
        ("args", Json::obj(args)),
    ])
}

fn thread_meta_json(tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(tid as f64)),
        ("name", Json::Str("thread_name".to_string())),
        (
            "args",
            Json::obj(vec![("name", Json::Str(name.to_string()))]),
        ),
    ])
}

/// Write the buffered spans to `path` (Chrome trace-event JSON array)
/// and `<path with .jsonl>` (one span object per line), draining the
/// buffer.  No-op returning `None` when tracing never recorded anything;
/// uses the configured output path when `path` is `None`.
pub fn flush(path: Option<&Path>) -> std::io::Result<Option<(PathBuf, usize)>> {
    let (events, threads, configured) = {
        let mut st = state().lock().unwrap_or_else(|e| e.into_inner());
        let events: Vec<SpanEvent> = st.events.drain(..).collect();
        (events, st.threads.clone(), st.out.clone())
    };
    let Some(path) = path.map(Path::to_path_buf).or(configured) else {
        return Ok(None);
    };
    if events.is_empty() {
        return Ok(None);
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut arr: Vec<Json> = threads
        .iter()
        .map(|(tid, name)| thread_meta_json(*tid, name))
        .collect();
    arr.extend(events.iter().map(event_json));
    let doc = Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("droppedSpans", Json::Num(dropped() as f64)),
    ]);
    std::fs::write(&path, doc.to_string())?;
    let jsonl = path.with_extension("jsonl");
    let mut lines = String::new();
    for ev in &events {
        let mut obj = vec![
            ("name", Json::Str(ev.name.clone())),
            ("cat", Json::Str(ev.cat.to_string())),
            ("tid", Json::Num(ev.tid as f64)),
            ("depth", Json::Num(ev.depth as f64)),
            ("ts_us", Json::Num(ev.ts_us as f64)),
            ("dur_us", Json::Num(ev.dur_us as f64)),
        ];
        if let Some(name) = threads.get(&ev.tid) {
            obj.push(("thread", Json::Str(name.clone())));
        }
        for (k, v) in &ev.args {
            obj.push((k, Json::Str(v.clone())));
        }
        lines.push_str(&Json::obj(obj).to_string());
        lines.push('\n');
    }
    std::fs::write(&jsonl, lines)?;
    Ok(Some((path, events.len())))
}

/// Open a trace span.  `span!("cat", "name {}", args...)` returns an RAII
/// guard; bind it (`let _sp = span!(...)`) so it covers the scope.  When
/// tracing is disabled this is one atomic load — the format arguments
/// are **not** evaluated.
#[macro_export]
macro_rules! span {
    ($cat:expr, $($fmt:tt)*) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::Span::start($cat, format!($($fmt)*))
        } else {
            $crate::obs::trace::Span::off()
        }
    };
}

/// Unit tests touching the process-global trace/log state serialize
/// through this lock (logging's tests share it).
#[cfg(test)]
pub(crate) static TEST_GATE: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    use super::TEST_GATE as GATE;

    #[test]
    fn disabled_spans_are_inert() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        configure(false, None);
        let before = buffered();
        {
            let sp = span!("test", "never-{}", "formatted");
            assert!(!sp.is_recording());
        }
        assert_eq!(buffered(), before, "disabled span must not record");
    }

    #[test]
    fn spans_nest_and_record_depth() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        configure(true, None);
        drain();
        {
            let _outer = Span::start("test", "outer").arg("k", 7);
            let _inner = Span::start("test", "inner");
        }
        configure(false, None);
        let evs = drain();
        let outer = evs.iter().find(|e| e.name == "outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "inner").unwrap();
        assert_eq!(outer.depth, 0);
        assert_eq!(inner.depth, 1);
        assert_eq!(outer.tid, inner.tid);
        assert_eq!(outer.args, vec![("k", "7".to_string())]);
        // inner closes first -> recorded first; both within the outer window
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1);
    }

    #[test]
    fn flush_writes_chrome_and_jsonl() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        configure(true, None);
        drain();
        drop(Span::start("test", "flushed"));
        configure(false, None);
        let dir = std::env::temp_dir().join(format!("perp-trace-{}", std::process::id()));
        let path = dir.join("trace.json");
        let (out, n) = flush(Some(&path)).unwrap().unwrap();
        assert!(n >= 1);
        let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let evs = doc.req("traceEvents").as_arr().unwrap();
        assert!(evs.iter().any(|e| e.req("ph").as_str() == Some("M")));
        assert!(evs
            .iter()
            .any(|e| e.req("ph").as_str() == Some("X")
                && e.req("name").as_str() == Some("flushed")));
        let jsonl = std::fs::read_to_string(out.with_extension("jsonl")).unwrap();
        assert!(jsonl.lines().count() >= 1);
        for line in jsonl.lines() {
            Json::parse(line).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

}
