//! PEFT state and merge algebra: the paper's §3.2 contribution, host-side.
//!
//! [`LoraState`] holds the (A, B) adapter pair for every adapted linear;
//! [`merge`] implements the four merge rules and their invariants:
//!
//! | variant    | forward                      | merge                        | sparsity kept |
//! |------------|------------------------------|------------------------------|---------------|
//! | LoRA       | Wx + s·B(Ax)                 | W + s·BA                     | ✗             |
//! | LoRA-Prune | Wx + s·B(Ax)                 | M ⊙ (W + s·BA)               | ✓ (damages)   |
//! | ScaleLoRA  | ((BA) ⊙ W)x                  | (BA) ⊙ W                     | ✓             |
//! | MaskLoRA   | (W + M ⊙ s·BA)x              | W + M ⊙ s·BA                 | ✓             |
//!
//! Initialisation follows the paper exactly: additive variants use B = 0
//! (identity start); ScaleLoRA uses A = B = 1/sqrt(r) so BA == 1.

pub mod merge;

use std::collections::BTreeMap;

use crate::runtime::ModelManifest;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Retraining mode (mirrors python's ALL_MODES).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Full,
    Biases,
    Ln,
    BiasesLn,
    Head,
    Embed,
    Lora,
    LoraPrune,
    MaskLora,
    MaskLoraStd,
    ScaleLora,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode, String> {
        Ok(match s {
            "full" => Mode::Full,
            "biases" => Mode::Biases,
            "ln" => Mode::Ln,
            "biases_ln" => Mode::BiasesLn,
            "head" => Mode::Head,
            "embed" => Mode::Embed,
            "lora" => Mode::Lora,
            "lora_prune" => Mode::LoraPrune,
            "masklora" => Mode::MaskLora,
            "masklora_std" => Mode::MaskLoraStd,
            "scalelora" => Mode::ScaleLora,
            other => return Err(format!("unknown retraining mode {other:?}")),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Full => "full",
            Mode::Biases => "biases",
            Mode::Ln => "ln",
            Mode::BiasesLn => "biases_ln",
            Mode::Head => "head",
            Mode::Embed => "embed",
            Mode::Lora => "lora",
            Mode::LoraPrune => "lora_prune",
            Mode::MaskLora => "masklora",
            Mode::MaskLoraStd => "masklora_std",
            Mode::ScaleLora => "scalelora",
        }
    }

    pub fn is_lora(&self) -> bool {
        matches!(
            self,
            Mode::Lora | Mode::LoraPrune | Mode::MaskLora | Mode::MaskLoraStd | Mode::ScaleLora
        )
    }

    /// Which lowered train-step executable this mode runs.  LoRA-Prune is a
    /// *merge-time* policy: it trains exactly like standard LoRA.
    pub fn executable(&self) -> &'static str {
        match self {
            Mode::Full => "train_full",
            Mode::Biases => "train_biases",
            Mode::Ln => "train_ln",
            Mode::BiasesLn => "train_biases_ln",
            Mode::Head => "train_head",
            Mode::Embed => "train_embed",
            Mode::Lora | Mode::LoraPrune => "train_lora",
            Mode::MaskLora => "train_masklora",
            Mode::MaskLoraStd => "train_masklora_std",
            Mode::ScaleLora => "train_scalelora",
        }
    }

    /// Manifest key for the trainable model-parameter set.
    pub fn trainable_key(&self) -> &'static str {
        match self {
            Mode::Lora | Mode::LoraPrune => "lora",
            Mode::MaskLora => "masklora",
            Mode::MaskLoraStd => "masklora_std",
            Mode::ScaleLora => "scalelora",
            other => other.name(),
        }
    }

    /// Can adapters merge back without destroying sparsity? (Table 2 col 2)
    pub fn mergeable_sparsity_preserving(&self) -> Option<bool> {
        match self {
            Mode::Lora => Some(false),
            Mode::LoraPrune | Mode::MaskLora | Mode::MaskLoraStd | Mode::ScaleLora => Some(true),
            _ => None, // subset modes have nothing to merge
        }
    }

    pub const ALL_LORA: [Mode; 4] = [Mode::Lora, Mode::LoraPrune, Mode::ScaleLora, Mode::MaskLora];
}

/// Adapter tensors for every adapted linear: `<linear>::A` and `<linear>::B`.
#[derive(Debug, Clone, Default)]
pub struct LoraState {
    pub tensors: BTreeMap<String, Tensor>,
}

impl LoraState {
    /// Paper init: A ~ N(0, 0.02), B = 0 (identity start) for additive
    /// variants; ones/sqrt(r) for ScaleLoRA.
    pub fn init(mm: &ModelManifest, mode: Mode, rng: &mut Rng) -> LoraState {
        assert!(mode.is_lora(), "adapters only exist for LoRA modes");
        let r = mm.cfg.lora_rank as f32;
        let mut tensors = BTreeMap::new();
        for (name, shape) in &mm.adapters {
            let t = if mode == Mode::ScaleLora {
                Tensor::full(shape, 1.0 / r.sqrt())
            } else if name.ends_with("::A") {
                Tensor::randn(shape, 0.02, rng)
            } else {
                Tensor::zeros(shape)
            };
            tensors.insert(name.clone(), t);
        }
        LoraState { tensors }
    }

    pub fn a(&self, linear: &str) -> &Tensor {
        &self.tensors[&format!("{linear}::A")]
    }
    pub fn b(&self, linear: &str) -> &Tensor {
        &self.tensors[&format!("{linear}::B")]
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        let old = self
            .tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown adapter {name:?}"));
        assert_eq!(old.shape(), t.shape(), "adapter shape change on {name:?}");
        self.tensors.insert(name.to_string(), t);
    }

    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn mode_roundtrip() {
        for m in [
            Mode::Full, Mode::Biases, Mode::Ln, Mode::BiasesLn, Mode::Head,
            Mode::Embed, Mode::Lora, Mode::LoraPrune, Mode::MaskLora,
            Mode::MaskLoraStd, Mode::ScaleLora,
        ] {
            assert_eq!(Mode::parse(m.name()).unwrap(), m);
        }
        assert!(Mode::parse("zzz").is_err());
    }

    #[test]
    fn mergeability_table_matches_paper() {
        assert_eq!(Mode::Lora.mergeable_sparsity_preserving(), Some(false));
        for m in [Mode::LoraPrune, Mode::ScaleLora, Mode::MaskLora] {
            assert_eq!(m.mergeable_sparsity_preserving(), Some(true));
        }
        assert_eq!(Mode::Biases.mergeable_sparsity_preserving(), None);
    }

    #[test]
    fn lora_prune_trains_like_lora() {
        assert_eq!(Mode::LoraPrune.executable(), "train_lora");
        assert_eq!(Mode::LoraPrune.trainable_key(), "lora");
    }

    #[test]
    fn init_identity_properties() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let mut rng = Rng::new(1);
        let add = LoraState::init(mm, Mode::MaskLora, &mut rng);
        // B = 0 everywhere
        for (n, t) in &add.tensors {
            if n.ends_with("::B") {
                assert_eq!(t.max_abs(), 0.0, "{n}");
            } else {
                assert!(t.max_abs() > 0.0, "{n}");
            }
        }
        let scale = LoraState::init(mm, Mode::ScaleLora, &mut rng);
        // BA == all-ones for every adapted linear
        for lin in &mm.prunable {
            let ba = crate::tensor::linalg::matmul(scale.b(lin), scale.a(lin));
            assert!(
                ba.allclose(&Tensor::ones(ba.shape()), 1e-5, 1e-5),
                "BA != 1 for {lin}"
            );
        }
    }

    #[test]
    fn adapter_count_matches_manifest() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let st = LoraState::init(mm, Mode::Lora, &mut Rng::new(2));
        let expect: usize = mm.adapters.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(st.param_count(), expect);
    }
}
