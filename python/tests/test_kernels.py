"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the mask density / rank / scale axes); each
property asserts allclose against compile.kernels.ref.  Gradients are checked
through jax.grad on a nonlinear scalarisation (sin-sum) so wrong transposes
cannot cancel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adamw_update,
    attention,
    layernorm,
    magnitude_threshold_mask,
    masked_lora_matmul,
    masked_matmul,
    mm_nn,
    mm_nt,
    nm_mask,
    ref,
    rmsnorm,
    scale_lora_init,
    scale_lora_matmul,
    wanda_score,
)

SETTINGS = dict(max_examples=12, deadline=None)


def rng_for(*dims):
    return np.random.default_rng(hash(dims) % (2**32))


dims = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128])
small_dims = st.sampled_from([8, 16, 32, 64])
ranks = st.sampled_from([1, 2, 4, 8, 16])
sparsities = st.sampled_from([0.0, 0.3, 0.5, 0.7, 0.95])


def allclose(a, b, atol=2e-4, rtol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# ---------------------------------------------------------------------------
# Dense matmuls.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=dims, m=dims, k=dims)
def test_mm_nt(n, m, k):
    r = rng_for(n, m, k)
    x = r.standard_normal((n, k), dtype=np.float32)
    w = r.standard_normal((m, k), dtype=np.float32)
    allclose(mm_nt(x, w), x @ w.T, atol=1e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(n=dims, m=dims, k=dims)
def test_mm_nn(n, m, k):
    r = rng_for(n, m, k)
    x = r.standard_normal((n, k), dtype=np.float32)
    w = r.standard_normal((k, m), dtype=np.float32)
    allclose(mm_nn(x, w), x @ w, atol=1e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(n=small_dims, m=small_dims, k=small_dims, sp=sparsities)
def test_masked_matmul_fwd_bwd(n, m, k, sp):
    r = rng_for(n, m, k, int(sp * 100))
    x = r.standard_normal((n, k), dtype=np.float32)
    w = r.standard_normal((m, k), dtype=np.float32)
    mask = (r.random((m, k)) >= sp).astype(np.float32)
    allclose(masked_matmul(x, w, mask), ref.masked_matmul(x, w, mask), atol=1e-3, rtol=1e-3)
    g = jax.grad(lambda x, w: jnp.sum(jnp.sin(masked_matmul(x, w, mask))), (0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum(jnp.sin(ref.masked_matmul(x, w, mask))), (0, 1))(x, w)
    for a, b in zip(g, gr):
        allclose(a, b, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# MaskLoRA / ScaleLoRA fused kernels.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=small_dims, m=small_dims, k=small_dims, r=ranks, sp=sparsities)
def test_masked_lora_fwd_bwd(n, m, k, r, sp):
    g = rng_for(n, m, k, r, int(sp * 100))
    x = g.standard_normal((n, k), dtype=np.float32)
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = (g.random((m, k)) >= sp).astype(np.float32)
    a = g.standard_normal((r, k), dtype=np.float32) * 0.2
    b = g.standard_normal((m, r), dtype=np.float32) * 0.2
    s = 2.0
    allclose(
        masked_lora_matmul(x, w, mask, a, b, s),
        ref.masked_lora_matmul(x, w, mask, a, b, s),
        atol=1e-3, rtol=1e-3,
    )
    gk = jax.grad(lambda *t: jnp.sum(jnp.sin(masked_lora_matmul(*t, s))), (0, 1, 3, 4))(
        x, w, mask, a, b
    )
    gref = jax.grad(lambda *t: jnp.sum(jnp.sin(ref.masked_lora_matmul(*t, s))), (0, 1, 3, 4))(
        x, w, mask, a, b
    )
    for gi, gri in zip(gk, gref):
        allclose(gi, gri, atol=2e-3, rtol=2e-3)


def test_masked_lora_zero_init_is_identity():
    """B = 0 ⇒ MaskLoRA forward equals the plain pruned forward (paper init)."""
    g = rng_for(7)
    x = g.standard_normal((16, 32), dtype=np.float32)
    w = g.standard_normal((24, 32), dtype=np.float32)
    mask = (g.random((24, 32)) >= 0.5).astype(np.float32)
    a = g.standard_normal((4, 32), dtype=np.float32)
    b = np.zeros((24, 4), dtype=np.float32)
    allclose(masked_lora_matmul(x, w, mask, a, b, 2.0), ref.masked_matmul(x, w, mask),
             atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(n=small_dims, m=small_dims, k=small_dims, r=ranks, sp=sparsities)
def test_scale_lora_fwd_bwd(n, m, k, r, sp):
    g = rng_for(n, m, k, r, int(sp * 10))
    x = g.standard_normal((n, k), dtype=np.float32)
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = (g.random((m, k)) >= sp).astype(np.float32)
    a, b = scale_lora_init(m, k, r)
    a = np.asarray(a) + g.standard_normal((r, k), dtype=np.float32) * 0.05
    b = np.asarray(b) + g.standard_normal((m, r), dtype=np.float32) * 0.05
    allclose(
        scale_lora_matmul(x, w, mask, a, b),
        ref.scale_lora_matmul(x, w, mask, a, b),
        atol=1e-3, rtol=1e-3,
    )
    gk = jax.grad(lambda *t: jnp.sum(jnp.sin(scale_lora_matmul(*t))), (0, 1, 3, 4))(
        x, w, mask, a, b
    )
    gref = jax.grad(lambda *t: jnp.sum(jnp.sin(ref.scale_lora_matmul(*t))), (0, 1, 3, 4))(
        x, w, mask, a, b
    )
    for gi, gri in zip(gk, gref):
        allclose(gi, gri, atol=2e-3, rtol=2e-3)


def test_scale_lora_init_is_identity():
    """ones/sqrt(r) init ⇒ BA == 1 ⇒ forward equals plain pruned forward."""
    g = rng_for(11)
    x = g.standard_normal((16, 32), dtype=np.float32)
    w = g.standard_normal((24, 32), dtype=np.float32)
    mask = (g.random((24, 32)) >= 0.5).astype(np.float32)
    a, b = scale_lora_init(24, 32, 16)
    allclose(scale_lora_matmul(x, w, mask, a, b), ref.masked_matmul(x, w, mask),
             atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Merge semantics: the sparsity-preservation invariants of PERP §3.2.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(m=small_dims, k=small_dims, r=ranks, sp=sparsities)
def test_merges_preserve_sparsity(m, k, r, sp):
    g = rng_for(m, k, r, int(sp * 100), 3)
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = (g.random((m, k)) >= sp).astype(np.float32)
    a = g.standard_normal((r, k), dtype=np.float32)
    b = g.standard_normal((m, r), dtype=np.float32)
    for merged in (
        ref.masklora_merge(w, mask, a, b, 2.0),
        ref.scalelora_merge(w, mask, a, b),
        ref.lora_prune_merge(w, mask, a, b, 2.0),
    ):
        assert np.all(np.asarray(merged)[np.asarray(mask) == 0.0] == 0.0)


@settings(**SETTINGS)
@given(n=small_dims, m=small_dims, k=small_dims, r=ranks)
def test_masklora_merge_matches_forward(n, m, k, r):
    """Post-merge plain forward == adapter forward (no degradation on merge)."""
    g = rng_for(n, m, k, r, 4)
    x = g.standard_normal((n, k), dtype=np.float32)
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = (g.random((m, k)) >= 0.5).astype(np.float32)
    a = g.standard_normal((r, k), dtype=np.float32) * 0.3
    b = g.standard_normal((m, r), dtype=np.float32) * 0.3
    merged = ref.masklora_merge(w, mask, a, b, 2.0)
    allclose(x @ np.asarray(merged).T, masked_lora_matmul(x, w, mask, a, b, 2.0),
             atol=1e-3, rtol=1e-3)
    merged_s = ref.scalelora_merge(w, mask, a, b)
    allclose(x @ np.asarray(merged_s).T, scale_lora_matmul(x, w, mask, a, b),
             atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 3]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 8, 16, 32, 64]),
    dh=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
)
def test_attention_fwd_bwd(b, h, s, dh, causal):
    g = rng_for(b, h, s, dh, causal)
    q = g.standard_normal((b, h, s, dh), dtype=np.float32)
    k = g.standard_normal((b, h, s, dh), dtype=np.float32)
    v = g.standard_normal((b, h, s, dh), dtype=np.float32)
    allclose(attention(q, k, v, causal), ref.attention(q, k, v, causal), atol=1e-4, rtol=1e-4)
    gk = jax.grad(lambda *t: jnp.sum(jnp.sin(attention(*t, causal))), (0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *t: jnp.sum(jnp.sin(ref.attention(*t, causal))), (0, 1, 2))(q, k, v)
    for a_, b_ in zip(gk, gr):
        allclose(a_, b_, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=dims, d=dims)
def test_layernorm_fwd_bwd(n, d):
    g = rng_for(n, d, 1)
    x = g.standard_normal((n, d), dtype=np.float32) * 3.0
    sc = g.standard_normal(d, dtype=np.float32)
    bi = g.standard_normal(d, dtype=np.float32)
    allclose(layernorm(x, sc, bi), ref.layernorm(x, sc, bi), atol=1e-4, rtol=1e-4)
    gk = jax.grad(lambda *t: jnp.sum(jnp.sin(layernorm(*t))), (0, 1, 2))(x, sc, bi)
    gr = jax.grad(lambda *t: jnp.sum(jnp.sin(ref.layernorm(*t))), (0, 1, 2))(x, sc, bi)
    for a_, b_ in zip(gk, gr):
        allclose(a_, b_, atol=1e-3, rtol=1e-3)


@settings(**SETTINGS)
@given(n=dims, d=dims)
def test_rmsnorm_fwd_bwd(n, d):
    g = rng_for(n, d, 2)
    x = g.standard_normal((n, d), dtype=np.float32) * 3.0
    sc = g.standard_normal(d, dtype=np.float32)
    allclose(rmsnorm(x, sc), ref.rmsnorm(x, sc), atol=1e-4, rtol=1e-4)
    gk = jax.grad(lambda *t: jnp.sum(jnp.sin(rmsnorm(*t))), (0, 1))(x, sc)
    gr = jax.grad(lambda *t: jnp.sum(jnp.sin(ref.rmsnorm(*t))), (0, 1))(x, sc)
    for a_, b_ in zip(gk, gr):
        allclose(a_, b_, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 5, 33, 257, 4096, 5000]),
    step=st.sampled_from([1, 2, 10, 1000]),
    wd=st.sampled_from([0.0, 0.01, 0.1]),
)
def test_adamw_matches_ref(n, step, wd):
    g = rng_for(n, step, int(wd * 100))
    p = g.standard_normal(n, dtype=np.float32)
    gr = g.standard_normal(n, dtype=np.float32)
    m = g.standard_normal(n, dtype=np.float32) * 0.1
    v = np.abs(g.standard_normal(n, dtype=np.float32)) * 0.01
    out = adamw_update(p, gr, m, v, jnp.float32(step), jnp.float32(1e-3), wd=wd)
    exp = ref.adamw(p, gr, m, v, step, 1e-3, wd=wd)
    for a_, b_ in zip(out, exp):
        allclose(a_, b_, atol=1e-5, rtol=1e-4)


def test_adamw_multidim_shapes():
    g = rng_for(99)
    for shape in [(3, 5), (2, 3, 4), (128, 64)]:
        p = g.standard_normal(shape, dtype=np.float32)
        gr = g.standard_normal(shape, dtype=np.float32)
        m = np.zeros(shape, np.float32)
        v = np.zeros(shape, np.float32)
        out = adamw_update(p, gr, m, v, jnp.float32(1), jnp.float32(1e-2))
        exp = ref.adamw(p, gr, m, v, 1, 1e-2)
        for a_, b_ in zip(out, exp):
            allclose(a_, b_, atol=1e-5, rtol=1e-4)
        assert out[0].shape == shape


# ---------------------------------------------------------------------------
# Mask kernels.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(m=dims, k=dims, thr=st.sampled_from([0.0, 0.25, 0.5, 1.0, 3.0]))
def test_magnitude_threshold(m, k, thr):
    g = rng_for(m, k, int(thr * 4))
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = magnitude_threshold_mask(w, jnp.float32(thr))
    allclose(mask, (np.abs(w) > thr).astype(np.float32))


@settings(**SETTINGS)
@given(m=dims, groups=st.sampled_from([2, 4, 8]), nm=st.sampled_from([(1, 4), (2, 4), (4, 8), (2, 8)]))
def test_nm_mask(m, groups, nm):
    n_, m_ = nm
    k = groups * m_
    g = rng_for(m, k, n_, m_)
    w = g.standard_normal((m, k), dtype=np.float32)
    mask = nm_mask(w, n_, m_)
    allclose(mask, ref.semistructured_mask(w, n_, m_))
    # invariant: every group keeps exactly n entries
    kept = np.asarray(mask).reshape(m, k // m_, m_).sum(-1)
    assert np.all(kept == n_)


def test_nm_mask_with_ties():
    """Duplicate magnitudes must still keep exactly n per group."""
    w = np.ones((4, 8), dtype=np.float32)
    mask = np.asarray(nm_mask(w, 2, 4))
    assert np.all(mask.reshape(4, 2, 4).sum(-1) == 2)
    allclose(mask, ref.semistructured_mask(w, 2, 4))


@settings(**SETTINGS)
@given(m=dims, k=dims)
def test_wanda_score(m, k):
    g = rng_for(m, k, 7)
    w = g.standard_normal((m, k), dtype=np.float32)
    nrm = np.abs(g.standard_normal(k, dtype=np.float32))
    allclose(wanda_score(w, nrm), ref.wanda_scores(w, nrm), atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(m=small_dims, k=small_dims, sp=sparsities)
def test_wanda_mask_rowwise_budget(m, k, sp):
    """ref.wanda_mask prunes exactly round(sp*in) per row (paper's comparison group)."""
    g = rng_for(m, k, int(sp * 100), 9)
    w = g.standard_normal((m, k), dtype=np.float32)
    nrm = np.abs(g.standard_normal(k, dtype=np.float32)) + 0.1
    mask = np.asarray(ref.wanda_mask(w, nrm, sp))
    pruned_per_row = (mask == 0).sum(axis=1)
    assert np.all(pruned_per_row == int(round(sp * k)))
