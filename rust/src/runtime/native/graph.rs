//! The native transformer graph: a hand-rolled forward + reverse pass over
//! the manifest-described GPT family (pre-LN, learned positions, GELU MLP,
//! optional biases / RMSNorm — the exact architecture of
//! `python/compile/model.py::forward`).
//!
//! The backward pass is activation-checkpointed the cheap way: [`forward`]
//! records a [`Tape`] (normed activations, attention probabilities, effective
//! weights) and [`backward`] walks it in reverse, accumulating gradients
//! *only* for the requested leaves — subset retraining modes therefore skip
//! every weight-gradient GEMM, which is PERP's efficiency argument realised
//! natively.

use std::collections::{BTreeMap, HashSet};

use crate::runtime::manifest::ModelManifest;
use crate::tensor::{linalg, pool, Tensor};

use super::ops;

// The per-linear dispatch seam: every masked contraction below routes
// through [`masked_fwd`]/[`masked_bwd_dx`] on the weight's resolved layout.
pub use crate::tensor::sparse::{SparseView, WeightLayout};

/// How the six per-block linears are parametrised (mirrors model.py modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModeKind {
    /// Plain masked forward — all subset modes (full, biases, ln, ...).
    Subset,
    /// Frozen-sparse W plus the unmasked low-rank path (standard LoRA).
    Lora,
    /// MaskLoRA: W·M + M ⊙ (s·BA).  Also covers masklora_std (same math,
    /// the std/optimized split is a device-kernel distinction).
    MaskLora,
    /// ScaleLoRA: (BA) ⊙ (W·M) multiplicative adapters.
    ScaleLora,
}

impl ModeKind {
    pub fn from_key(key: &str) -> ModeKind {
        match key {
            "lora" => ModeKind::Lora,
            "masklora" | "masklora_std" => ModeKind::MaskLora,
            "scalelora" => ModeKind::ScaleLora,
            _ => ModeKind::Subset,
        }
    }
}

/// Borrowed model state for one execution, resolved from the Feed.
pub struct GraphIn<'a> {
    pub mm: &'a ModelManifest,
    pub params: &'a BTreeMap<String, &'a Tensor>,
    pub masks: &'a BTreeMap<String, &'a Tensor>,
    /// Adapter tensors keyed `<linear>::A` / `<linear>::B` (LoRA modes only).
    pub adapters: Option<&'a BTreeMap<String, &'a Tensor>>,
    pub mode: ModeKind,
    /// Per-weight execution layouts + cached CSR forms.  Empty = fused
    /// masked kernels everywhere (the default path).
    pub sparse: SparseView<'a>,
}

impl<'a> GraphIn<'a> {
    pub(super) fn p(&self, name: &str) -> &'a Tensor {
        self.params
            .get(name)
            .copied()
            .unwrap_or_else(|| panic!("graph: missing parameter {name:?}"))
    }
    pub(super) fn m(&self, name: &str) -> &'a Tensor {
        self.masks
            .get(name)
            .copied()
            .unwrap_or_else(|| panic!("graph: missing mask {name:?}"))
    }
    fn adapter(&self, wname: &str, tag: &str) -> &'a Tensor {
        let key = format!("{wname}::{tag}");
        self.adapters
            .unwrap_or_else(|| panic!("graph: mode needs adapters but none were fed"))
            .get(&key)
            .copied()
            .unwrap_or_else(|| panic!("graph: missing adapter {key:?}"))
    }
    fn scale(&self) -> f32 {
        self.mm.cfg.lora_scale as f32
    }
}

// ---------------------------------------------------------------------------
// Tape.
// ---------------------------------------------------------------------------

struct LinTape {
    /// W ⊙ M materialised — only ScaleLoRA needs it (as the adapter gate);
    /// the other modes read W and M through the fused masked kernels.
    wm: Option<Tensor>,
    /// Effective weight for the z-parametrised modes (MaskLoRA / ScaleLoRA).
    z: Option<Tensor>,
    /// x Aᵀ intermediate of the standard-LoRA path.
    u: Option<Tensor>,
}

impl LinTape {
    fn recycle(self) {
        if let Some(wm) = self.wm {
            pool::recycle(wm);
        }
        if let Some(z) = self.z {
            pool::recycle(z);
        }
        if let Some(u) = self.u {
            pool::recycle(u);
        }
    }
}

struct BlockTape {
    ln1: ops::NormCache,
    h1: Tensor,
    q: LinTape,
    k: LinTape,
    v: LinTape,
    qh: Tensor,
    kh: Tensor,
    vh: Tensor,
    probs: Tensor,
    attn_merged: Tensor,
    o: LinTape,
    ln2: ops::NormCache,
    h2: Tensor,
    fc: LinTape,
    fc_pre: Tensor,
    gelu_out: Tensor,
    proj: LinTape,
}

pub struct Tape {
    pub b: usize,
    pub s: usize,
    blocks: Vec<BlockTape>,
    fln: ops::NormCache,
    h_final: Tensor,
    /// (B*S, V)
    pub logits: Tensor,
}

impl Tape {
    /// Consume the tape into (full logits, per-layer (K, V) head planes) —
    /// the serving prefill's cache extraction.  The K/V tensors are
    /// (B, H, S, dh), exactly the `prefill` output layout; every other
    /// activation buffer is returned to the thread-local pool.
    pub fn into_logits_and_kv(self) -> (Tensor, Vec<(Tensor, Tensor)>) {
        let mut kv = Vec::with_capacity(self.blocks.len());
        for bt in self.blocks {
            let BlockTape {
                ln1,
                h1,
                q,
                k,
                v,
                qh,
                kh,
                vh,
                probs,
                attn_merged,
                o,
                ln2,
                h2,
                fc,
                fc_pre,
                gelu_out,
                proj,
            } = bt;
            ln1.recycle();
            ln2.recycle();
            for lt in [q, k, v, o, fc, proj] {
                lt.recycle();
            }
            for t in [h1, qh, probs, attn_merged, h2, fc_pre, gelu_out] {
                pool::recycle(t);
            }
            kv.push((kh, vh));
        }
        self.fln.recycle();
        pool::recycle(self.h_final);
        (self.logits, kv)
    }

    /// Return every tape buffer to the thread-local pool — for callers that
    /// have fully consumed the activations (train and eval steps).
    pub fn recycle(self) {
        let (logits, kv) = self.into_logits_and_kv();
        pool::recycle(logits);
        for (k, v) in kv {
            pool::recycle(k);
            pool::recycle(v);
        }
    }

    /// Consume the tape into the calibration/reconstruction capture list:
    /// `(tap_param_name, X)` pairs in forward order (the layout
    /// `builtin_tap_names` describes).  The captured activations are
    /// *moved* out of the tape — the old capture path cloned each of them
    /// mid-forward — and every other buffer is recycled.
    pub fn into_captures(self) -> Vec<(String, Tensor)> {
        let Tape { blocks, fln, h_final, logits, .. } = self;
        let mut cap = Vec::with_capacity(blocks.len() * 4);
        for (i, bt) in blocks.into_iter().enumerate() {
            let BlockTape {
                ln1,
                h1,
                q,
                k,
                v,
                qh,
                kh,
                vh,
                probs,
                attn_merged,
                o,
                ln2,
                h2,
                fc,
                fc_pre,
                gelu_out,
                proj,
            } = bt;
            ln1.recycle();
            ln2.recycle();
            for lt in [q, k, v, o, fc, proj] {
                lt.recycle();
            }
            for t in [qh, kh, vh, probs, fc_pre] {
                pool::recycle(t);
            }
            cap.push((format!("h{i}_attn_q_w"), h1));
            cap.push((format!("h{i}_attn_o_w"), attn_merged));
            cap.push((format!("h{i}_mlp_fc_w"), h2));
            cap.push((format!("h{i}_mlp_proj_w"), gelu_out));
        }
        fln.recycle();
        pool::recycle(h_final);
        pool::recycle(logits);
        cap
    }
}

// ---------------------------------------------------------------------------
// Forward.
// ---------------------------------------------------------------------------

fn norm_fwd(gi: &GraphIn, prefix: &str, x: &Tensor) -> (Tensor, ops::NormCache) {
    let scale = gi.p(&format!("{prefix}_scale"));
    if gi.mm.cfg.norm == "layernorm" {
        ops::layernorm_fwd(x, scale, gi.p(&format!("{prefix}_bias")))
    } else {
        ops::rmsnorm_fwd(x, scale)
    }
}

fn norm_bwd(
    gi: &GraphIn,
    prefix: &str,
    cache: &ops::NormCache,
    dy: &Tensor,
    grads: &mut Grads,
) -> Tensor {
    let sname = format!("{prefix}_scale");
    let scale = gi.p(&sname);
    if gi.mm.cfg.norm == "layernorm" {
        let bname = format!("{prefix}_bias");
        let want = grads.wanted(&sname) || grads.wanted(&bname);
        let (dx, pg) = ops::layernorm_bwd(cache, scale, dy, want);
        if let Some((dscale, dbias)) = pg {
            grads.add(sname, dscale);
            grads.add(bname, dbias);
        }
        dx
    } else {
        let want = grads.wanted(&sname);
        let (dx, pg) = ops::rmsnorm_bwd(cache, scale, dy, want);
        if let Some(dscale) = pg {
            grads.add(sname, dscale);
        }
        dx
    }
}

/// One `spmm.<layout>` tick per dispatched contraction — each arm is its
/// own call site so the [`crate::count!`] handle caching stays valid.
pub(crate) fn count_spmm(layout: WeightLayout) {
    match layout {
        WeightLayout::Dense => crate::count!("spmm.dense"),
        WeightLayout::Masked => crate::count!("spmm.masked"),
        WeightLayout::Csr => crate::count!("spmm.csr"),
        WeightLayout::Bsr => crate::count!("spmm.bsr"),
        WeightLayout::CsrF16 => crate::count!("spmm.csr_f16"),
        WeightLayout::CsrQ8 => crate::count!("spmm.csr_q8"),
        WeightLayout::BsrF16 => crate::count!("spmm.bsr_f16"),
        WeightLayout::BsrQ8 => crate::count!("spmm.bsr_q8"),
    }
}

/// `x @ (W⊙M)ᵀ` through the weight's resolved [`WeightLayout`] — the
/// forward/decode dispatch seam.  CSR touches only surviving weights; BSR
/// streams dense tiles with pipelined accumulators; the quantised forms
/// dequantise in-register; Masked reads W and M fused; Dense materialises
/// `W⊙M` (the pre-fusion baseline, kept for A/B benches and
/// `--layout dense`).
pub(crate) fn masked_fwd(gi: &GraphIn, wname: &str, x: &Tensor) -> Tensor {
    let layout = gi.sparse.layout_of(wname);
    count_spmm(layout);
    match layout {
        WeightLayout::Masked => linalg::matmul_nt_masked(x, gi.p(wname), gi.m(wname)),
        WeightLayout::Dense => {
            let wm = gi.p(wname).hadamard(gi.m(wname));
            let y = linalg::matmul_nt(x, &wm);
            pool::recycle(wm);
            y
        }
        _ => gi
            .sparse
            .get_form(wname)
            .expect("compressed layout implies a cached form")
            .spmm_nt(x),
    }
}

/// `dy @ (W⊙M)` through the weight's resolved layout — the backward-dx
/// seam.  Weight-gradient accumulation stays dense in all layouts: masks
/// freeze pruned coordinates, so only the dx contraction profits from
/// compression.  Quantised forms refuse the backward contraction
/// (`SparseForm::spmm` returns `None`) — gradients must never be
/// approximate, so they fall back to the exact masked kernel.
pub(crate) fn masked_bwd_dx(gi: &GraphIn, wname: &str, dy: &Tensor) -> Tensor {
    let layout = gi.sparse.layout_of(wname);
    match layout {
        WeightLayout::Masked => {
            crate::count!("spmm.masked");
            linalg::matmul_masked(dy, gi.p(wname), gi.m(wname))
        }
        WeightLayout::Dense => {
            crate::count!("spmm.dense");
            let wm = gi.p(wname).hadamard(gi.m(wname));
            let dx = linalg::matmul(dy, &wm);
            pool::recycle(wm);
            dx
        }
        _ => {
            let form = gi
                .sparse
                .get_form(wname)
                .expect("compressed layout implies a cached form");
            match form.spmm(dy) {
                Some(dx) => {
                    count_spmm(layout);
                    dx
                }
                None => {
                    crate::count!("spmm.masked");
                    linalg::matmul_masked(dy, gi.p(wname), gi.m(wname))
                }
            }
        }
    }
}

fn linear_fwd(gi: &GraphIn, base: &str, x: &Tensor) -> (Tensor, LinTape) {
    let wname = format!("{base}_w");
    let (mut y, wm, z, u) = match gi.mode {
        // layout-dispatched masked forward: pruned weights are skipped in
        // the kernel (Masked) or never even loaded (Csr)
        ModeKind::Subset => (masked_fwd(gi, &wname, x), None, None, None),
        ModeKind::Lora => {
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let s = gi.scale();
            let u = linalg::matmul_nt(x, a); // (n, r)
            let low = linalg::matmul_nt(&u, bmat); // (n, out)
            let y = masked_fwd(gi, &wname, x).zip(&low, |p, q| p + s * q);
            (y, None, None, Some(u))
        }
        ModeKind::MaskLora => {
            let (w, mask) = (gi.p(&wname), gi.m(&wname));
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let s = gi.scale();
            let ba = linalg::matmul(bmat, a); // (out, in)
            // z = W⊙M + s·(BA)⊙M: materialised once, reused by the backward
            let z = w.hadamard(mask).zip(&ba.hadamard(mask), |p, q| p + s * q);
            (linalg::matmul_nt(x, &z), None, Some(z), None)
        }
        ModeKind::ScaleLora => {
            let (w, mask) = (gi.p(&wname), gi.m(&wname));
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let ba = linalg::matmul(bmat, a);
            let wm = w.hadamard(mask); // the adapter gate — backward needs it
            let z = ba.hadamard(&wm);
            (linalg::matmul_nt(x, &z), Some(wm), Some(z), None)
        }
    };
    if gi.mm.cfg.use_bias {
        ops::add_bias(&mut y, gi.p(&format!("{base}_b")));
    }
    (y, LinTape { wm, z, u })
}

fn linear_bwd(
    gi: &GraphIn,
    base: &str,
    x: &Tensor,
    dy: &Tensor,
    tape: &LinTape,
    grads: &mut Grads,
) -> Tensor {
    let wname = format!("{base}_w");
    if gi.mm.cfg.use_bias {
        let bname = format!("{base}_b");
        if grads.wanted(&bname) {
            grads.add(bname, ops::col_sums(dy));
        }
    }
    match gi.mode {
        ModeKind::Subset => {
            if grads.wanted(&wname) {
                // masked-matmul VJP: pruned entries stay exactly zero
                let dw = linalg::matmul_tn(dy, x).hadamard(gi.m(&wname));
                grads.add(wname.clone(), dw);
            }
            // dx = dy @ (W⊙M) through the layout seam
            masked_bwd_dx(gi, &wname, dy)
        }
        ModeKind::Lora => {
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let s = gi.scale();
            let u = tape.u.as_ref().expect("lora tape");
            let du = linalg::matmul(dy, bmat).scale(s); // (n, r)
            grads.add(format!("{wname}::B"), linalg::matmul_tn(dy, u).scale(s));
            grads.add(format!("{wname}::A"), linalg::matmul_tn(&du, x));
            masked_bwd_dx(gi, &wname, dy).add(&linalg::matmul(&du, a))
        }
        ModeKind::MaskLora => {
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let z = tape.z.as_ref().expect("masklora tape");
            let dz = linalg::matmul_tn(dy, x); // (out, in)
            let (da, db) = ops::adapter_vjp(&dz, gi.m(&wname), a, bmat, gi.scale());
            grads.add(format!("{wname}::B"), db);
            grads.add(format!("{wname}::A"), da);
            linalg::matmul(dy, z)
        }
        ModeKind::ScaleLora => {
            let a = gi.adapter(&wname, "A");
            let bmat = gi.adapter(&wname, "B");
            let z = tape.z.as_ref().expect("scalelora tape");
            let wm = tape.wm.as_ref().expect("scalelora tape gate");
            let dz = linalg::matmul_tn(dy, x);
            let (da, db) = ops::adapter_vjp(&dz, wm, a, bmat, 1.0);
            grads.add(format!("{wname}::B"), db);
            grads.add(format!("{wname}::A"), da);
            linalg::matmul(dy, z)
        }
    }
}

/// Token ids (B, S) -> logits, recording the tape for [`backward`].  The
/// calibration/reconstruction capture points (ln1/attn-merged/ln2/gelu
/// activations) live on the tape — consume it with
/// [`Tape::into_captures`] instead of cloning mid-forward.
pub fn forward(gi: &GraphIn, tokens: &[i32], b: usize, s: usize) -> Tape {
    let cfg = &gi.mm.cfg;
    let (h, dh) = (cfg.n_heads, cfg.d_head());
    let mut cur = ops::embed_fwd(tokens, b, s, gi.p("embed_tokens"), gi.p("embed_pos"));
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let p = format!("h{i}_");
        let (h1, ln1) = norm_fwd(gi, &format!("{p}ln1"), &cur);
        let (q2, qt) = linear_fwd(gi, &format!("{p}attn_q"), &h1);
        let (k2, kt) = linear_fwd(gi, &format!("{p}attn_k"), &h1);
        let (v2, vt) = linear_fwd(gi, &format!("{p}attn_v"), &h1);
        let qh = ops::split_heads(&q2, b, s, h, dh);
        let kh = ops::split_heads(&k2, b, s, h, dh);
        let vh = ops::split_heads(&v2, b, s, h, dh);
        let (oh, probs) = ops::attention_fwd(&qh, &kh, &vh);
        let attn_merged = ops::merge_heads(&oh, b, s, h, dh);
        let (o2, ot) = linear_fwd(gi, &format!("{p}attn_o"), &attn_merged);
        let res_mid = cur.add(&o2);
        let (h2, ln2) = norm_fwd(gi, &format!("{p}ln2"), &res_mid);
        let (fc_pre, fct) = linear_fwd(gi, &format!("{p}mlp_fc"), &h2);
        let gelu_out = ops::gelu(&fc_pre);
        let (proj2, pt) = linear_fwd(gi, &format!("{p}mlp_proj"), &gelu_out);
        cur = res_mid.add(&proj2);
        blocks.push(BlockTape {
            ln1,
            h1,
            q: qt,
            k: kt,
            v: vt,
            qh,
            kh,
            vh,
            probs,
            attn_merged,
            o: ot,
            ln2,
            h2,
            fc: fct,
            fc_pre,
            gelu_out,
            proj: pt,
        });
    }
    let (h_final, fln) = norm_fwd(gi, "final_ln", &cur);
    let logits = linalg::matmul_nt(&h_final, gi.p("head_w"));
    Tape { b, s, blocks, fln, h_final, logits }
}

// ---------------------------------------------------------------------------
// Backward.
// ---------------------------------------------------------------------------

/// Gradient sink filtered by the trainable-leaf set.
pub struct Grads {
    wants: HashSet<String>,
    map: BTreeMap<String, Tensor>,
}

impl Grads {
    fn wanted(&self, name: &str) -> bool {
        self.wants.contains(name)
    }
    fn add(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if !self.wants.contains(&name) {
            return;
        }
        match self.map.entry(name) {
            std::collections::btree_map::Entry::Occupied(mut e) => {
                let acc = e.get().add(&t);
                e.insert(acc);
            }
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(t);
            }
        }
    }
}

/// Reverse pass: gradients of the mean loss wrt every leaf named in `wants`
/// (model parameters and/or `<linear>::A/B` adapters), given dL/dlogits.
pub fn backward(
    gi: &GraphIn,
    tape: &Tape,
    tokens: &[i32],
    dlogits: &Tensor,
    wants: HashSet<String>,
) -> BTreeMap<String, Tensor> {
    let cfg = &gi.mm.cfg;
    let (b, s) = (tape.b, tape.s);
    let (h, dh) = (cfg.n_heads, cfg.d_head());
    let mut grads = Grads { wants, map: BTreeMap::new() };

    if grads.wanted("head_w") {
        grads.add("head_w", linalg::matmul_tn(dlogits, &tape.h_final));
    }
    // everything past the final norm is only needed for leaves living below
    // it — the "head" retraining subset stops here (one GEMM per step, which
    // IS its efficiency pitch)
    let below_final_norm = grads
        .wants
        .iter()
        .any(|n| n != "head_w" && n != "final_ln_scale" && n != "final_ln_bias");
    if !below_final_norm && !grads.wanted("final_ln_scale") && !grads.wanted("final_ln_bias") {
        return grads.map;
    }
    let dh_final = linalg::matmul(dlogits, gi.p("head_w"));
    let mut dcur = norm_bwd(gi, "final_ln", &tape.fln, &dh_final, &mut grads);
    if !below_final_norm {
        return grads.map;
    }

    for (i, bt) in tape.blocks.iter().enumerate().rev() {
        let p = format!("h{i}_");
        // ---- MLP branch (res_out = res_mid + proj(gelu(fc(ln2(res_mid))))) --
        let dg = linear_bwd(gi, &format!("{p}mlp_proj"), &bt.gelu_out, &dcur, &bt.proj, &mut grads);
        let dfc = ops::gelu_vjp(&bt.fc_pre, &dg);
        let dh2 = linear_bwd(gi, &format!("{p}mlp_fc"), &bt.h2, &dfc, &bt.fc, &mut grads);
        let dres_mid = dcur.add(&norm_bwd(gi, &format!("{p}ln2"), &bt.ln2, &dh2, &mut grads));
        // ---- attention branch (res_mid = res_in + o(attn(qkv(ln1(res_in))))) --
        let d_attn_merged =
            linear_bwd(gi, &format!("{p}attn_o"), &bt.attn_merged, &dres_mid, &bt.o, &mut grads);
        let doh = ops::split_heads(&d_attn_merged, b, s, h, dh);
        let (dqh, dkh, dvh) = ops::attention_bwd(&bt.qh, &bt.kh, &bt.vh, &bt.probs, &doh);
        let dq2 = ops::merge_heads(&dqh, b, s, h, dh);
        let dk2 = ops::merge_heads(&dkh, b, s, h, dh);
        let dv2 = ops::merge_heads(&dvh, b, s, h, dh);
        let dh1 = linear_bwd(gi, &format!("{p}attn_q"), &bt.h1, &dq2, &bt.q, &mut grads)
            .add(&linear_bwd(gi, &format!("{p}attn_k"), &bt.h1, &dk2, &bt.k, &mut grads))
            .add(&linear_bwd(gi, &format!("{p}attn_v"), &bt.h1, &dv2, &bt.v, &mut grads));
        dcur = dres_mid.add(&norm_bwd(gi, &format!("{p}ln1"), &bt.ln1, &dh1, &mut grads));
    }

    if grads.wanted("embed_pos") {
        grads.add("embed_pos", ops::embed_pos_bwd(&dcur, b, s));
    }
    if grads.wanted("embed_tokens") {
        grads.add("embed_tokens", ops::embed_tokens_bwd(tokens, &dcur, cfg.vocab));
    }
    grads.map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ModelCfg, ModelManifest};
    use crate::util::rng::Rng;

    /// A micro model (builtin-shaped but tiny) for gradient checking.
    fn micro(norm: &str, use_bias: bool) -> ModelManifest {
        let cfg = ModelCfg {
            name: "micro".into(),
            vocab: 17,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            seq_len: 6,
            d_ff: 32,
            use_bias,
            norm: norm.into(),
            lora_rank: 3,
            lora_alpha: 6.0,
            lora_scale: 2.0,
            train_batch: 2,
            eval_batch: 2,
            calib_rows: 4,
            serve_slots: 4,
        };
        ModelManifest::builtin(cfg)
    }

    struct State {
        params: BTreeMap<String, Tensor>,
        masks: BTreeMap<String, Tensor>,
        adapters: BTreeMap<String, Tensor>,
        tokens: Vec<i32>,
    }

    fn random_state(mm: &ModelManifest, seed: u64) -> State {
        let mut rng = Rng::new(seed);
        let mut params = BTreeMap::new();
        for p in &mm.params {
            let t = if p.name.ends_with("_scale") {
                Tensor::randn(&p.shape, 0.1, &mut rng).map(|v| v + 1.0)
            } else {
                Tensor::randn(&p.shape, 0.3, &mut rng)
            };
            params.insert(p.name.clone(), t);
        }
        let mut masks = BTreeMap::new();
        for n in &mm.prunable {
            let shape = mm.param_shape(n);
            let m = Tensor::randn(shape, 1.0, &mut rng).map(|v| if v > -0.3 { 1.0 } else { 0.0 });
            masks.insert(n.clone(), m);
        }
        let mut adapters = BTreeMap::new();
        for (n, shape) in &mm.adapters {
            adapters.insert(n.clone(), Tensor::randn(shape, 0.2, &mut rng));
        }
        let b = mm.cfg.train_batch;
        let s = mm.cfg.seq_len;
        let tokens: Vec<i32> =
            (0..b * s).map(|_| rng.below(mm.cfg.vocab as u64) as i32).collect();
        State { params, masks, adapters, tokens }
    }

    fn loss_of(mm: &ModelManifest, st: &State, mode: ModeKind) -> f32 {
        let params: BTreeMap<String, &Tensor> =
            st.params.iter().map(|(k, v)| (k.clone(), v)).collect();
        let masks: BTreeMap<String, &Tensor> =
            st.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
        let adapters: BTreeMap<String, &Tensor> =
            st.adapters.iter().map(|(k, v)| (k.clone(), v)).collect();
        let gi = GraphIn {
            mm,
            params: &params,
            masks: &masks,
            adapters: if mode == ModeKind::Subset { None } else { Some(&adapters) },
            mode,
            sparse: SparseView::default(),
        };
        let b = mm.cfg.train_batch;
        let s = mm.cfg.seq_len;
        let tape = forward(&gi, &st.tokens, b, s);
        let (loss, _) = ops::ce_grad(&tape.logits, &st.tokens, b, s);
        loss
    }

    fn grads_of(
        mm: &ModelManifest,
        st: &State,
        mode: ModeKind,
        wants: &[&str],
    ) -> BTreeMap<String, Tensor> {
        let params: BTreeMap<String, &Tensor> =
            st.params.iter().map(|(k, v)| (k.clone(), v)).collect();
        let masks: BTreeMap<String, &Tensor> =
            st.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
        let adapters: BTreeMap<String, &Tensor> =
            st.adapters.iter().map(|(k, v)| (k.clone(), v)).collect();
        let gi = GraphIn {
            mm,
            params: &params,
            masks: &masks,
            adapters: if mode == ModeKind::Subset { None } else { Some(&adapters) },
            mode,
            sparse: SparseView::default(),
        };
        let b = mm.cfg.train_batch;
        let s = mm.cfg.seq_len;
        let tape = forward(&gi, &st.tokens, b, s);
        let (_, dlogits) = ops::ce_grad(&tape.logits, &st.tokens, b, s);
        let wants: HashSet<String> = wants.iter().map(|s| s.to_string()).collect();
        backward(&gi, &tape, &st.tokens, &dlogits, wants)
    }

    /// Central-difference check of d(loss)/d(leaf[idx]).
    fn check_grad(
        mm: &ModelManifest,
        st: &mut State,
        mode: ModeKind,
        leaf: &str,
        idx: usize,
        got: f32,
    ) {
        let eps = 2e-2f32;
        let is_adapter = leaf.contains("::");
        let bump = |st: &mut State, delta: f32| {
            let t = if is_adapter {
                st.adapters.get_mut(leaf).unwrap()
            } else {
                st.params.get_mut(leaf).unwrap()
            };
            t.data_mut()[idx] += delta;
        };
        bump(st, eps);
        let lp = loss_of(mm, st, mode);
        bump(st, -2.0 * eps);
        let lm = loss_of(mm, st, mode);
        bump(st, eps);
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - got).abs() < 2e-3 + 0.05 * fd.abs().max(got.abs()),
            "{leaf}[{idx}] (mode {mode:?}): finite-diff {fd} vs backward {got}"
        );
    }

    #[test]
    fn full_mode_gradients_match_finite_difference() {
        let mm = micro("layernorm", true);
        let mut st = random_state(&mm, 1);
        let leaves = [
            "embed_tokens",
            "embed_pos",
            "h0_attn_q_w",
            "h0_attn_o_b",
            "h1_mlp_fc_w",
            "h1_mlp_proj_w",
            "h0_ln1_scale",
            "h1_ln2_bias",
            "final_ln_scale",
            "head_w",
        ];
        let grads = grads_of(&mm, &st, ModeKind::Subset, &leaves);
        assert_eq!(grads.len(), leaves.len());
        let mut rng = Rng::new(7);
        for leaf in leaves {
            let g = &grads[leaf];
            // pick the largest-|grad| coordinate plus a random one
            let (mut best, mut bv) = (0usize, 0.0f32);
            for (i, &v) in g.data().iter().enumerate() {
                if v.abs() > bv {
                    best = i;
                    bv = v.abs();
                }
            }
            let rand_i = rng.below(g.numel() as u64) as usize;
            for idx in [best, rand_i] {
                check_grad(&mm, &mut st, ModeKind::Subset, leaf, idx, g.data()[idx]);
            }
        }
    }

    #[test]
    fn masked_weight_gradients_are_masked() {
        let mm = micro("layernorm", true);
        let st = random_state(&mm, 2);
        let grads = grads_of(&mm, &st, ModeKind::Subset, &["h0_attn_v_w"]);
        let g = &grads["h0_attn_v_w"];
        let m = &st.masks["h0_attn_v_w"];
        for (gv, mv) in g.data().iter().zip(m.data()) {
            if *mv == 0.0 {
                assert_eq!(*gv, 0.0, "gradient leaked through the mask");
            }
        }
    }

    #[test]
    fn rmsnorm_nobias_gradients_match_finite_difference() {
        let mm = micro("rmsnorm", false);
        let mut st = random_state(&mm, 3);
        let leaves = ["h0_ln1_scale", "h1_attn_k_w", "final_ln_scale", "embed_pos"];
        let grads = grads_of(&mm, &st, ModeKind::Subset, &leaves);
        let mut rng = Rng::new(11);
        for leaf in leaves {
            let g = &grads[leaf];
            let idx = rng.below(g.numel() as u64) as usize;
            check_grad(&mm, &mut st, ModeKind::Subset, leaf, idx, g.data()[idx]);
        }
    }

    #[test]
    fn adapter_gradients_match_finite_difference_per_mode() {
        for mode in [ModeKind::Lora, ModeKind::MaskLora, ModeKind::ScaleLora] {
            let mm = micro("layernorm", true);
            let mut st = random_state(&mm, 4);
            let leaves = ["h0_attn_q_w::A", "h0_attn_q_w::B", "h1_mlp_proj_w::A", "h0_attn_o_b"];
            let grads = grads_of(&mm, &st, mode, &leaves);
            let mut rng = Rng::new(13);
            for leaf in leaves {
                let g = &grads[leaf];
                let idx = rng.below(g.numel() as u64) as usize;
                check_grad(&mm, &mut st, mode, leaf, idx, g.data()[idx]);
            }
        }
    }

    #[test]
    fn capture_taps_are_in_forward_order() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let st = random_state(mm, 5);
        let params: BTreeMap<String, &Tensor> =
            st.params.iter().map(|(k, v)| (k.clone(), v)).collect();
        let masks: BTreeMap<String, &Tensor> =
            st.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
        let gi = GraphIn {
            mm,
            params: &params,
            masks: &masks,
            adapters: None,
            mode: ModeKind::Subset,
            sparse: SparseView::default(),
        };
        let b = mm.cfg.eval_batch;
        let s = mm.cfg.seq_len;
        let tokens: Vec<i32> = vec![1; b * s];
        let cap = forward(&gi, &tokens, b, s).into_captures();
        let names: Vec<String> = cap.iter().map(|(n, _)| n.clone()).collect();
        let expect = crate::runtime::manifest::builtin_tap_names(&mm.cfg);
        assert_eq!(names, expect);
        for (n, x) in &cap {
            assert_eq!(x.shape(), &[b * s, mm.param_shape(n)[1]], "{n}");
        }
    }

    fn layout_forward_and_dx_vs_masked(layout: WeightLayout, bitwise: bool) {
        use crate::tensor::sparse::{LayoutPolicy, SparseStore};
        let mm = micro("layernorm", true);
        let st = random_state(&mm, 6);
        let params: BTreeMap<String, &Tensor> =
            st.params.iter().map(|(k, v)| (k.clone(), v)).collect();
        let masks: BTreeMap<String, &Tensor> =
            st.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
        let store = SparseStore::build(
            LayoutPolicy::Fixed(layout),
            mm.prunable.iter().map(|n| (n.clone(), &st.params[n.as_str()], &st.masks[n.as_str()])),
        );
        assert_eq!(store.forms.len(), mm.prunable.len());
        let b = mm.cfg.train_batch;
        let s = mm.cfg.seq_len;
        let base = GraphIn {
            mm: &mm,
            params: &params,
            masks: &masks,
            adapters: None,
            mode: ModeKind::Subset,
            sparse: SparseView::default(),
        };
        let routed = GraphIn { sparse: store.view(), ..base };
        let t_masked = forward(&base, &st.tokens, b, s);
        let t_routed = forward(&routed, &st.tokens, b, s);
        if bitwise {
            for (x, y) in t_routed.logits.data().iter().zip(t_masked.logits.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} forward diverged", layout.name());
            }
        } else {
            // quantised layouts are approximate by design
            assert!(
                t_routed.logits.allclose(&t_masked.logits, 0.35, 0.35),
                "{} forward drifted beyond its error model",
                layout.name()
            );
        }
        // backward dx path: gradients of a below-the-linears leaf agree
        // (quantised forms fall back to the exact masked kernel, so this
        // holds tightly for every layout given identical upstream logits)
        let (_, dl) = ops::ce_grad(&t_masked.logits, &st.tokens, b, s);
        let wants: HashSet<String> = ["embed_tokens".to_string()].into();
        let gm = backward(&base, &t_masked, &st.tokens, &dl, wants.clone());
        let gc = backward(&routed, &t_masked, &st.tokens, &dl, wants);
        assert!(gc["embed_tokens"].allclose(&gm["embed_tokens"], 1e-6, 1e-5));
    }

    #[test]
    fn csr_layout_forward_and_dx_match_masked() {
        layout_forward_and_dx_vs_masked(WeightLayout::Csr, true);
    }

    #[test]
    fn bsr_layout_forward_and_dx_match_masked_bitwise() {
        layout_forward_and_dx_vs_masked(WeightLayout::Bsr, true);
    }

    #[test]
    fn quantised_layouts_forward_within_error_model() {
        layout_forward_and_dx_vs_masked(WeightLayout::CsrQ8, false);
        layout_forward_and_dx_vs_masked(WeightLayout::BsrQ8, false);
        layout_forward_and_dx_vs_masked(WeightLayout::CsrF16, false);
    }
}
