//! Content addressing for plan stages.
//!
//! A stage's key is an FNV-1a 64-bit chain over everything that determines
//! its output: the model, the experiment config fields the stages read, the
//! seed, the backend, and the canonical JSON of *every* stage up to and
//! including this one.  Properties that fall out:
//!
//! * two plans sharing a prefix share that prefix's artifacts (a sweep over
//!   retrain iterations reuses one pruned checkpoint);
//! * editing any upstream stage, the config, or the seed changes every
//!   downstream key — stale artifacts can never be picked up;
//! * keys are stable across processes and platforms (pure integer math over
//!   canonical strings).

use crate::config::ExperimentConfig;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a byte slice, continuing from `state`.
pub fn fnv1a(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One-shot FNV-1a over a byte slice, hex-rendered — the content fingerprint
/// `Export` stages record so byte-identical checkpoints can be skipped.
pub fn fnv1a_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a(FNV_OFFSET, bytes))
}

/// A chained content key.  `push` derives the next stage's key; the hex form
/// names the artifact directory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Key(pub u64);

impl Key {
    pub fn push(self, s: &str) -> Key {
        // separator byte keeps ("ab","c") distinct from ("a","bc")
        Key(fnv1a(fnv1a(self.0, &[0x1f]), s.as_bytes()))
    }

    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

/// The chain root: every config field a stage can read, plus model, seed and
/// backend.  Deliberately explicit (not `Debug`-derived) so adding unrelated
/// config fields later does not invalidate existing caches by accident.
pub fn base_key(cfg: &ExperimentConfig, seed: u64) -> Key {
    let basis = format!(
        "perp-plan-v1|{}|{}|seed={}|pre={}@{}|re={}|grid={:?}|calib={}|rc={}@{}|tasks={}|eb={}|ds={}",
        cfg.model,
        cfg.backend,
        seed,
        cfg.pretrain_steps,
        cfg.pretrain_lr,
        cfg.retrain_steps,
        cfg.lr_grid,
        cfg.calib_seqs,
        cfg.recon_steps,
        cfg.recon_lr,
        cfg.items_per_task,
        cfg.eval_batches,
        cfg.data_seed,
    );
    let key = Key(fnv1a(FNV_OFFSET, basis.as_bytes()));
    // Exact layouts (dense/masked/csr/bsr/auto) are bitwise-identical, so
    // they share one artifact space — switching them must not invalidate
    // caches.  Quantised policies change eval outputs and key separately.
    match crate::tensor::sparse::LayoutPolicy::parse(&cfg.layout) {
        Ok(p) if p.may_quantise() => key.push(&format!("layout={}", p.name())),
        _ => key,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_reference_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn chain_is_order_sensitive_and_separated() {
        let k = Key(FNV_OFFSET);
        assert_ne!(k.push("a").push("b"), k.push("b").push("a"));
        assert_ne!(k.push("ab").push("c"), k.push("a").push("bc"));
        assert_eq!(k.push("x"), k.push("x"));
    }

    #[test]
    fn exact_layouts_share_keys_quantised_segregate() {
        let mut c = ExperimentConfig::quick("gpt-nano");
        let k_auto = base_key(&c, 0);
        for exact in ["dense", "masked", "csr", "bsr"] {
            c.layout = exact.to_string();
            assert_eq!(k_auto, base_key(&c, 0), "exact layout {exact} must share artifacts");
        }
        let mut seen = vec![k_auto];
        for quant in ["auto-q", "csr-q8", "bsr-f16"] {
            c.layout = quant.to_string();
            let k = base_key(&c, 0);
            assert!(!seen.contains(&k), "quantised layout {quant} must key separately");
            seen.push(k);
        }
    }

    #[test]
    fn base_key_tracks_config_and_seed() {
        let c = ExperimentConfig::quick("gpt-nano");
        let k0 = base_key(&c, 0);
        assert_ne!(k0, base_key(&c, 1));
        let mut c2 = c.clone();
        c2.retrain_steps += 1;
        assert_ne!(k0, base_key(&c2, 0));
        let mut c3 = c.clone();
        c3.model = "gpt-tiny".to_string();
        assert_ne!(k0, base_key(&c3, 0));
        assert_eq!(k0, base_key(&ExperimentConfig::quick("gpt-nano"), 0));
        assert_eq!(k0.hex().len(), 16);
    }
}
