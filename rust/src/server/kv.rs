//! Per-stream KV-cache slots for the serving layer.
//!
//! The cache owns one (slots, H, S, dh) K and V tensor per layer — exactly
//! the `prefill` output / `decode_step` input planes — plus the slot
//! allocator the dynamic batcher draws from.  `prefill` results are adopted
//! wholesale (row `b` of the prefill batch is slot `b`); each `decode_step`
//! returns only the new K/V rows, which are written in place here, so the
//! backend itself stays stateless.

use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

pub struct KvCache {
    /// Per-layer K planes, each (slots, H, S, dh).
    pub k: Vec<Tensor>,
    /// Per-layer V planes, same shape.
    pub v: Vec<Tensor>,
    pub slots: usize,
    pub heads: usize,
    pub seq: usize,
    pub dh: usize,
    free: Vec<usize>,
}

impl KvCache {
    pub fn new(cfg: &ModelCfg) -> KvCache {
        let (slots, heads, seq, dh) = (cfg.serve_slots, cfg.n_heads, cfg.seq_len, cfg.d_head());
        let shape = [slots, heads, seq, dh];
        KvCache {
            k: (0..cfg.n_layers).map(|_| Tensor::zeros(&shape)).collect(),
            v: (0..cfg.n_layers).map(|_| Tensor::zeros(&shape)).collect(),
            slots,
            heads,
            seq,
            dh,
            free: (0..slots).rev().collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by live streams (the occupancy `/metrics` and
    /// the `serve.kv.occupied` histogram report).
    pub fn occupied(&self) -> usize {
        self.slots - self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        self.free.pop()
    }

    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.free.push(slot);
    }

    /// Adopt one stream's prefill result: copy slot row `slot` of the
    /// (slots, H, S, dh) prefill output planes into this cache.
    pub fn adopt_prefill(&mut self, slot: usize, layer: usize, k: &Tensor, v: &Tensor) {
        let n = self.heads * self.seq * self.dh;
        let span = slot * n..(slot + 1) * n;
        self.k[layer].data_mut()[span.clone()].copy_from_slice(&k.data()[span.clone()]);
        self.v[layer].data_mut()[span.clone()].copy_from_slice(&v.data()[span]);
    }

    /// Write one decode step's new K/V rows (the (slots, H, dh) `knew::`/
    /// `vnew::` outputs) at position `pos` of stream `slot`.
    pub fn write_new(&mut self, slot: usize, pos: usize, layer: usize, knew: &Tensor, vnew: &Tensor) {
        debug_assert!(pos < self.seq, "cache overflow: pos {pos} >= seq {}", self.seq);
        let (heads, seq, dh) = (self.heads, self.seq, self.dh);
        for hd in 0..heads {
            let src = slot * heads * dh + hd * dh;
            let dst = slot * heads * seq * dh + hd * seq * dh + pos * dh;
            self.k[layer].data_mut()[dst..dst + dh].copy_from_slice(&knew.data()[src..src + dh]);
            self.v[layer].data_mut()[dst..dst + dh].copy_from_slice(&vnew.data()[src..src + dh]);
        }
    }

    /// Resident cache size: layers × 2 (K and V) × slots × H × S × dh × 4 B.
    pub fn bytes(&self) -> usize {
        kv_bytes_for(self.n_layers(), self.slots, self.heads, self.seq, self.dh)
    }
}

/// The KV-cache memory formula (documented in rust/README.md):
/// `n_layers * 2 * slots * n_heads * seq_len * d_head * 4` bytes
/// = `n_layers * 2 * slots * seq_len * d_model * 4` bytes.
pub fn kv_bytes_for(layers: usize, slots: usize, heads: usize, seq: usize, dh: usize) -> usize {
    layers * 2 * slots * heads * seq * dh * 4
}

/// Formula applied to a model config.
pub fn kv_bytes(cfg: &ModelCfg) -> usize {
    kv_bytes_for(cfg.n_layers, cfg.serve_slots, cfg.n_heads, cfg.seq_len, cfg.d_head())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelCfg;

    fn cache() -> KvCache {
        KvCache::new(&ModelCfg::builtin("gpt-nano").unwrap())
    }

    #[test]
    fn slot_allocator_roundtrips() {
        let mut c = cache();
        assert_eq!(c.free_slots(), c.slots);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.free_slots(), c.slots - 2);
        c.release(a);
        assert_eq!(c.free_slots(), c.slots - 1);
        for _ in 0..c.slots - 1 {
            assert!(c.alloc().is_some());
        }
        assert!(c.alloc().is_none());
    }

    #[test]
    fn writes_land_at_the_right_position() {
        let mut c = cache();
        let (slots, heads, seq, dh) = (c.slots, c.heads, c.seq, c.dh);
        let mut knew = Tensor::zeros(&[slots, heads, dh]);
        knew.data_mut()[2 * heads * dh] = 5.0; // slot 2, head 0, first lane
        let vnew = knew.clone();
        c.write_new(2, 3, 1, &knew, &vnew);
        let idx = 2 * heads * seq * dh + 3 * dh;
        assert_eq!(c.k[1].data()[idx], 5.0);
        assert_eq!(c.v[1].data()[idx], 5.0);
        // other layers and slots untouched
        assert_eq!(c.k[0].data()[idx], 0.0);
    }

    #[test]
    fn memory_formula_matches_planes() {
        let c = cache();
        let expect: usize =
            c.k.iter().chain(c.v.iter()).map(|t| t.numel() * 4).sum();
        assert_eq!(c.bytes(), expect);
        let cfg = ModelCfg::builtin("gpt-nano").unwrap();
        assert_eq!(kv_bytes(&cfg), expect);
    }
}
