//! Wanda (Sun et al. 2023): prune by |W_ij| · ‖X_j‖₂.
//!
//! The input norms come from the calibration Gram matrices captured by the
//! `calib_stats` executable: ‖X_j‖₂ = sqrt(G_jj) with G = Σ_batches XᵀX —
//! Wanda's activation statistics and SparseGPT's Hessian share one pass.
//!
//! Comparison group is the output row (the paper's default for LLMs): each
//! row prunes exactly round(sparsity·in) entries.  N:M masks apply the same
//! scores within input groups.

use crate::tensor::Tensor;

use super::{mask_smallest_k, Pattern};

/// Per-input-feature L2 norms from an accumulated Gram matrix.
pub fn norms_from_gram(gram: &Tensor) -> Vec<f32> {
    let n = gram.rows();
    (0..n).map(|j| gram.at2(j, j).max(0.0).sqrt()).collect()
}

/// Wanda scores S = |W| ⊙ norms (broadcast over rows).
pub fn scores(w: &Tensor, x_norms: &[f32]) -> Tensor {
    assert_eq!(w.cols(), x_norms.len());
    let mut s = Tensor::zeros(w.shape());
    for r in 0..w.rows() {
        let wrow = w.row(r);
        let srow = s.row_mut(r);
        for j in 0..wrow.len() {
            srow[j] = wrow[j].abs() * x_norms[j];
        }
    }
    s
}

/// Wanda mask for one linear.
pub fn mask(w: &Tensor, gram: &Tensor, pattern: Pattern) -> Tensor {
    let norms = norms_from_gram(gram);
    let s = scores(w, &norms);
    match pattern {
        Pattern::Unstructured(f) => {
            let k = (f * w.cols() as f64).round() as usize;
            let mut out = Tensor::zeros(w.shape());
            for r in 0..w.rows() {
                let rowmask = mask_smallest_k(s.row(r), k);
                out.row_mut(r).copy_from_slice(&rowmask);
            }
            out
        }
        Pattern::SemiStructured { n, m } => {
            super::semistructured::nm_mask_scored(w, &s, n, m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn norms_extracted_from_gram() {
        // X with known column norms
        let x = Tensor::new(&[2, 3], vec![3.0, 0.0, 1.0, 4.0, 0.0, 1.0]);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let n = norms_from_gram(&gram);
        assert!((n[0] - 5.0).abs() < 1e-5);
        assert_eq!(n[1], 0.0);
        assert!((n[2] - 2f32.sqrt()).abs() < 1e-5);
    }

    #[test]
    fn outlier_features_survive_magnitude_would_not() {
        // The paper's core motivation: a small weight feeding a huge feature
        // must be kept by Wanda even though magnitude would prune it.
        let w = Tensor::new(&[1, 4], vec![0.1, 1.0, 0.9, 0.8]);
        let mut gram = Tensor::zeros(&[4, 4]);
        gram.set2(0, 0, 10_000.0); // outlier feature 0
        for j in 1..4 {
            gram.set2(j, j, 1.0);
        }
        let m = mask(&w, &gram, Pattern::Unstructured(0.5));
        assert_eq!(m.at2(0, 0), 1.0, "outlier weight must survive");
        // while plain magnitude would prune index 0 first
        let mag = mask_smallest_k(w.row(0), 2);
        assert_eq!(mag[0], 0.0);
    }

    #[test]
    fn rowwise_budget_exact() {
        prop::check("wanda_row_budget", 20, |g| {
            let rows = g.dim(8).max(1);
            let cols = g.dim_multiple_of(4, 64);
            let sp = g.sparsity() as f64;
            let w = Tensor::new(&[rows, cols], g.tensor(rows * cols, 1.0));
            let x = Tensor::new(&[16, cols], g.tensor(16 * cols, 1.0));
            let gram = linalg::matmul(&x.transpose2(), &x);
            let m = mask(&w, &gram, Pattern::Unstructured(sp));
            let k = (sp * cols as f64).round() as usize;
            for r in 0..rows {
                let pruned = m.row(r).iter().filter(|&&x| x == 0.0).count();
                assert_eq!(pruned, k);
            }
        });
    }

    #[test]
    fn nm_variant_respects_pattern() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let x = Tensor::randn(&[32, 16], 1.0, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let m = mask(&w, &gram, Pattern::SemiStructured { n: 2, m: 4 });
        assert!(super::super::semistructured::check_nm(&m, 2, 4));
    }
}
