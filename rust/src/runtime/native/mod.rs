//! The hermetic, rayon-parallel native CPU backend.
//!
//! Implements every graph the manifest names — eval/score (plain and
//! adapter-active), the per-mode train steps (forward + hand-rolled reverse
//! pass + AdamW), calibration Grams, reconstruction capture, the per-shape
//! layer-wise reconstruction steps, and the serving pair
//! (`prefill`/`decode_step`, see [`decode`]) — directly on host tensors.
//! Semantics are pinned to `python/compile/kernels/ref.py` by golden-fixture
//! and finite-difference tests.
//!
//! Per-step activation buffers are recycled through the thread-local
//! [`crate::tensor::pool`], so steady-state train/decode loops run without
//! allocator churn (`PERP_TAPE_POOL=0` disables reuse).
//!
//! "Compilation" is input validation against the manifest's `ExecSpec`; the
//! prepared set backs [`Backend::compiled_count`] so cache-behaviour tests
//! and benches read the same way as on the PJRT backend.

pub mod decode;
pub mod graph;
pub mod ops;
pub mod verify;

use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::obs::counters::Registry;
use crate::runtime::manifest::{is_lora_mode, split_adapter_name, DType, Manifest, ModelManifest};
use crate::runtime::{Backend, Feed, Outputs};
use crate::tensor::{linalg, pool, Tensor};

use graph::{GraphIn, ModeKind, SparseView};

pub struct NativeBackend {
    manifest: Manifest,
    /// Per-instance execution ledger — one `exec.<name>` counter per
    /// executable, summed by [`Backend::exec_count`].  The global
    /// [`Registry`] additionally sees `backend.exec.<name>` so `/metrics`
    /// and `repro profile` report per-executable breakdowns.
    execs: Registry,
    prepared: Mutex<BTreeSet<(String, String)>>,
}

impl NativeBackend {
    /// Backend over the builtin model fleet (the hermetic default).
    pub fn new() -> NativeBackend {
        NativeBackend::with_manifest(Manifest::builtin())
    }

    /// Backend over a custom manifest (tests with micro models).
    pub fn with_manifest(manifest: Manifest) -> NativeBackend {
        NativeBackend {
            manifest,
            execs: Registry::new(),
            prepared: Mutex::new(BTreeSet::new()),
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prepare(&self, model: &str, exec: &str) -> Result<()> {
        let mm = self.manifest.model(model)?;
        mm.exec(exec)?;
        self.prepared.lock().unwrap().insert((model.to_string(), exec.to_string()));
        Ok(())
    }

    fn run(&self, model: &str, exec: &str, feed: &Feed) -> Result<Outputs> {
        let mm = self.manifest.model(model)?;
        let spec = mm.exec(exec)?;
        // ---- resolve + validate every declared input --------------------
        let mut f32s: BTreeMap<&str, &Tensor> = BTreeMap::new();
        let mut i32s: BTreeMap<&str, (&[usize], &[i32])> = BTreeMap::new();
        for ispec in &spec.inputs {
            match ispec.dtype {
                DType::F32 => {
                    let t = feed
                        .get_tensor(&ispec.name)
                        .with_context(|| {
                            format!("missing f32 input {:?} feeding {exec:?}", ispec.name)
                        })?;
                    if t.shape() != &ispec.shape[..] {
                        bail!(
                            "input {:?}: tensor shape {:?} != spec {:?}",
                            ispec.name,
                            t.shape(),
                            ispec.shape
                        );
                    }
                    f32s.insert(ispec.name.as_str(), t);
                }
                DType::I32 => {
                    let (shape, data) = feed
                        .get_ints(&ispec.name)
                        .with_context(|| {
                            format!("missing i32 input {:?} feeding {exec:?}", ispec.name)
                        })?;
                    if shape != &ispec.shape[..] {
                        bail!(
                            "input {:?}: shape {shape:?} != spec {:?}",
                            ispec.name,
                            ispec.shape
                        );
                    }
                    i32s.insert(ispec.name.as_str(), (shape, data));
                }
            }
        }
        self.prepared
            .lock()
            .unwrap()
            .insert((model.to_string(), exec.to_string()));
        self.execs.add(&format!("exec.{exec}"), 1);
        Registry::global().add(&format!("backend.exec.{exec}"), 1);
        let _sp = crate::span!("backend", "{exec}").arg("model", model);

        // ---- dispatch ----------------------------------------------------
        let sv = gather_sparse(mm, feed);
        match exec {
            "eval_loss" | "eval_loss_lora" => {
                eval_loss(mm, &f32s, &i32s, sv, exec.ends_with("_lora"))
            }
            "score" | "score_lora" => score(mm, &f32s, &i32s, sv, exec.ends_with("_lora")),
            "calib_stats" => capture(mm, &f32s, &i32s, sv, true),
            "capture_inputs" => capture(mm, &f32s, &i32s, sv, false),
            "prefill" => decode::prefill(mm, &f32s, &i32s, sv),
            "decode_step" => decode::decode_step(mm, &f32s, &i32s, sv),
            "verify_step" => verify::verify_step(mm, &f32s, &i32s, sv),
            e if e.starts_with("train_") => {
                train(mm, &f32s, &i32s, sv, e.strip_prefix("train_").unwrap())
            }
            e if e.starts_with("linear_fwd_") => {
                let y0 = linalg::matmul_nt(f32s["x"], f32s["w"]);
                Ok(Outputs { values: vec![("y0".to_string(), y0)] })
            }
            e if e.starts_with("recon_masklora_") => recon_masklora(mm, &f32s),
            e if e.starts_with("recon_full_") => recon_full(&f32s),
            other => bail!("native backend: unimplemented executable {other:?}"),
        }
    }

    fn exec_count(&self) -> u64 {
        self.execs.sum_prefixed("exec.")
    }

    fn compiled_count(&self) -> usize {
        self.prepared.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// Gathering helpers.
// ---------------------------------------------------------------------------

fn gather_params<'a>(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &'a Tensor>,
) -> (BTreeMap<String, &'a Tensor>, BTreeMap<String, &'a Tensor>) {
    let params = mm
        .params
        .iter()
        .map(|p| (p.name.clone(), f32s[format!("p::{}", p.name).as_str()]))
        .collect();
    let masks = mm
        .prunable
        .iter()
        .map(|n| (n.clone(), f32s[format!("m::{n}").as_str()]))
        .collect();
    (params, masks)
}

/// Collect the feed's compressed-layout side channel for this model's
/// prunable weights.  Empty when the caller attached nothing — every
/// linear then runs the fused masked kernels.
fn gather_sparse<'a>(mm: &ModelManifest, feed: &Feed<'a>) -> SparseView<'a> {
    let mut sv = SparseView::default();
    for n in &mm.prunable {
        if let Some(l) = feed.get_weight_layout(n) {
            sv.layouts.insert(n.clone(), l);
        }
        if let Some(f) = feed.get_form(n) {
            sv.forms.insert(n.clone(), f);
        }
    }
    sv
}

fn gather_adapters<'a>(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &'a Tensor>,
) -> BTreeMap<String, &'a Tensor> {
    mm.adapters
        .iter()
        .map(|(name, _)| {
            let (lin, tag) = split_adapter_name(name);
            (name.clone(), f32s[format!("{tag}::{lin}").as_str()])
        })
        .collect()
}

fn tokens_in<'a>(i32s: &BTreeMap<&str, (&'a [usize], &'a [i32])>) -> (usize, usize, &'a [i32]) {
    let (shape, data) = i32s["tokens"];
    (shape[0], shape[1], data)
}

fn scalar_in(f32s: &BTreeMap<&str, &Tensor>, name: &str) -> f32 {
    f32s[name].data()[0]
}

// ---------------------------------------------------------------------------
// Model-level executables.
// ---------------------------------------------------------------------------

fn eval_loss(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
    lora: bool,
) -> Result<Outputs> {
    let (params, masks) = gather_params(mm, f32s);
    let adapters = lora.then(|| gather_adapters(mm, f32s));
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: adapters.as_ref(),
        mode: if lora { ModeKind::Lora } else { ModeKind::Subset },
        sparse,
    };
    let (b, s, toks) = tokens_in(i32s);
    let tape = graph::forward(&gi, toks, b, s);
    let (sum, count) = ops::ce_sums(&tape.logits, toks, b, s);
    tape.recycle();
    Ok(Outputs {
        values: vec![
            ("loss_sum".to_string(), Tensor::scalar(sum as f32)),
            ("count".to_string(), Tensor::scalar(count as f32)),
        ],
    })
}

fn score(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
    lora: bool,
) -> Result<Outputs> {
    let (params, masks) = gather_params(mm, f32s);
    let adapters = lora.then(|| gather_adapters(mm, f32s));
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: adapters.as_ref(),
        mode: if lora { ModeKind::Lora } else { ModeKind::Subset },
        sparse,
    };
    let (b, s, toks) = tokens_in(i32s);
    let tape = graph::forward(&gi, toks, b, s);
    let (scores, counts) = ops::sequence_scores(&tape.logits, toks, f32s["tmask"], b, s);
    tape.recycle();
    Ok(Outputs {
        values: vec![
            ("scores".to_string(), Tensor::new(&[b], scores)),
            ("counts".to_string(), Tensor::new(&[b], counts)),
        ],
    })
}

/// `calib_stats` (grams = true) and `capture_inputs` (grams = false) share
/// one captured forward pass in plain masked mode.  The captured
/// activations are moved off the tape, not cloned mid-forward.
fn capture(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
    grams: bool,
) -> Result<Outputs> {
    let (params, masks) = gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (b, s, toks) = tokens_in(i32s);
    let cap = graph::forward(&gi, toks, b, s).into_captures();
    let values = cap
        .into_iter()
        .map(|(tap, x)| {
            if grams {
                let g = linalg::matmul_tn(&x, &x);
                pool::recycle(x);
                (format!("gram::{tap}"), g)
            } else {
                (format!("x::{tap}"), x)
            }
        })
        .collect();
    Ok(Outputs { values })
}

fn train(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
    mode_key: &str,
) -> Result<Outputs> {
    let trainable = mm
        .trainable
        .get(mode_key)
        .with_context(|| format!("no trainable set {mode_key:?} in manifest"))?;
    let lora = is_lora_mode(mode_key);
    let mut leaves: Vec<String> = trainable.clone();
    if lora {
        leaves.extend(mm.adapters.iter().map(|(n, _)| n.clone()));
    }
    let (params, masks) = gather_params(mm, f32s);
    let adapters = lora.then(|| gather_adapters(mm, f32s));
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: adapters.as_ref(),
        mode: ModeKind::from_key(mode_key),
        sparse,
    };
    let (b, s, toks) = tokens_in(i32s);
    let step = scalar_in(f32s, "step");
    let lr = scalar_in(f32s, "lr");

    let tape = graph::forward(&gi, toks, b, s);
    let (loss, dlogits) = ops::ce_grad(&tape.logits, toks, b, s);
    let wants: HashSet<String> = leaves.iter().cloned().collect();
    let mut grads = graph::backward(&gi, &tape, toks, &dlogits, wants);
    tape.recycle();
    pool::recycle(dlogits);

    let mut o_vals = Vec::with_capacity(leaves.len());
    let mut m_vals = Vec::with_capacity(leaves.len());
    let mut v_vals = Vec::with_capacity(leaves.len());
    for leaf in &leaves {
        let p: &Tensor = if leaf.contains("::") {
            adapters.as_ref().expect("lora leaves imply adapters")[leaf]
        } else {
            params[leaf]
        };
        // every trainable leaf has a gradient path; a missing entry means the
        // manifest and backward() disagree on names — fail loudly rather than
        // silently freezing the parameter under a zero gradient
        let g = grads
            .remove(leaf)
            .with_context(|| format!("backward produced no gradient for leaf {leaf:?}"))?;
        let m_in = f32s[format!("om::{leaf}").as_str()];
        let v_in = f32s[format!("ov::{leaf}").as_str()];
        let (p2, m2, v2) = ops::adamw(p, &g, m_in, v_in, step, lr);
        pool::recycle(g);
        o_vals.push((format!("o::{leaf}"), p2));
        m_vals.push((format!("om::{leaf}"), m2));
        v_vals.push((format!("ov::{leaf}"), v2));
    }
    let mut values = o_vals;
    values.extend(m_vals);
    values.extend(v_vals);
    values.push(("loss".to_string(), Tensor::scalar(loss)));
    Ok(Outputs { values })
}

// ---------------------------------------------------------------------------
// Per-shape reconstruction executables (PERP Eq. 1).
// ---------------------------------------------------------------------------

/// Shared: ŷ = x zᵀ against targets y0; loss = mean((ŷ-y0)²)·out_dim,
/// dŷ = 2(ŷ-y0)/rows.  Returns (loss, dy).
fn recon_loss_grad(y: &Tensor, y0: &Tensor) -> (f32, Tensor) {
    let rows = y.rows() as f64;
    let diff = y.sub(y0);
    let loss = diff.sq_norm() / rows;
    let dy = diff.scale(2.0 / rows as f32);
    pool::recycle(diff);
    (loss as f32, dy)
}

fn recon_masklora(mm: &ModelManifest, f32s: &BTreeMap<&str, &Tensor>) -> Result<Outputs> {
    let (x, y0, w, mask) = (f32s["x"], f32s["y0"], f32s["w"], f32s["mask"]);
    let (a, bmat) = (f32s["a"], f32s["b"]);
    let scale = mm.cfg.lora_scale as f32;
    let step = scalar_in(f32s, "step");
    let lr = scalar_in(f32s, "lr");

    let wm = w.hadamard(mask);
    let ba = linalg::matmul(bmat, a);
    let z = wm.zip(&ba.hadamard(mask), |p, q| p + scale * q);
    pool::recycle(wm);
    pool::recycle(ba);
    let y = linalg::matmul_nt(x, &z);
    pool::recycle(z);
    let (loss, dy) = recon_loss_grad(&y, y0);
    pool::recycle(y);
    let dz = linalg::matmul_tn(&dy, x);
    pool::recycle(dy);
    let (da, db) = ops::adapter_vjp(&dz, mask, a, bmat, scale);
    pool::recycle(dz);

    let (a2, ma2, va2) = ops::adamw(a, &da, f32s["om::a"], f32s["ov::a"], step, lr);
    let (b2, mb2, vb2) = ops::adamw(bmat, &db, f32s["om::b"], f32s["ov::b"], step, lr);
    pool::recycle(da);
    pool::recycle(db);
    Ok(Outputs {
        values: vec![
            ("o::a".to_string(), a2),
            ("o::b".to_string(), b2),
            ("om::a".to_string(), ma2),
            ("ov::a".to_string(), va2),
            ("om::b".to_string(), mb2),
            ("ov::b".to_string(), vb2),
            ("loss".to_string(), Tensor::scalar(loss)),
        ],
    })
}

fn recon_full(f32s: &BTreeMap<&str, &Tensor>) -> Result<Outputs> {
    let (x, y0, w, mask) = (f32s["x"], f32s["y0"], f32s["w"], f32s["mask"]);
    let step = scalar_in(f32s, "step");
    let lr = scalar_in(f32s, "lr");

    let wm = w.hadamard(mask);
    let y = linalg::matmul_nt(x, &wm);
    pool::recycle(wm);
    let (loss, dy) = recon_loss_grad(&y, y0);
    pool::recycle(y);
    // masked-matmul VJP: pruned entries get zero gradient and never move
    let dzt = linalg::matmul_tn(&dy, x);
    pool::recycle(dy);
    let dw = dzt.hadamard(mask);
    pool::recycle(dzt);
    let (w2, mw2, vw2) = ops::adamw(w, &dw, f32s["om::w"], f32s["ov::w"], step, lr);
    pool::recycle(dw);
    Ok(Outputs {
        values: vec![
            ("o::w".to_string(), w2),
            ("om::w".to_string(), mw2),
            ("ov::w".to_string(), vw2),
            ("loss".to_string(), Tensor::scalar(loss)),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ones_masks(mm: &ModelManifest) -> BTreeMap<String, Tensor> {
        mm.prunable
            .iter()
            .map(|n| (n.clone(), Tensor::ones(mm.param_shape(n))))
            .collect()
    }

    fn nano_feed_state(seed: u64) -> (NativeBackend, BTreeMap<String, Tensor>, BTreeMap<String, Tensor>) {
        let be = NativeBackend::new();
        let mm = be.model("gpt-nano").unwrap().clone();
        let mut rng = Rng::new(seed);
        let mut params = BTreeMap::new();
        for p in &mm.params {
            let t = if p.name.ends_with("_scale") {
                Tensor::ones(&p.shape)
            } else {
                Tensor::randn(&p.shape, 0.05, &mut rng)
            };
            params.insert(format!("p::{}", p.name), t);
        }
        let masks = ones_masks(&mm)
            .into_iter()
            .map(|(n, t)| (format!("m::{n}"), t))
            .collect();
        (be, params, masks)
    }

    #[test]
    fn recon_masklora_reduces_its_own_loss() {
        let be = NativeBackend::new();
        let mm = be.model("gpt-nano").unwrap().clone();
        let rows = mm.cfg.calib_rows;
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[rows, 32], 1.0, &mut rng);
        let w0 = Tensor::randn(&[32, 32], 0.2, &mut rng);
        let mask = Tensor::randn(&[32, 32], 1.0, &mut rng).map(|v| if v > 0.0 { 1.0 } else { 0.0 });
        let w = w0.hadamard(&mask);
        let y0 = linalg::matmul_nt(&x, &w0);
        let r = mm.cfg.lora_rank;
        let mut a = Tensor::randn(&[r, 32], 0.02, &mut rng);
        let mut b = Tensor::zeros(&[32, r]);
        let (mut ma, mut va) = (Tensor::zeros(&[r, 32]), Tensor::zeros(&[r, 32]));
        let (mut mb, mut vb) = (Tensor::zeros(&[32, r]), Tensor::zeros(&[32, r]));
        let (mut first, mut last) = (0.0f32, 0.0f32);
        for t in 1..=60u32 {
            let feed = Feed::new()
                .tensor("x", &x)
                .tensor("y0", &y0)
                .tensor("w", &w)
                .tensor("mask", &mask)
                .tensor("a", &a)
                .tensor("b", &b)
                .tensor("om::a", &ma)
                .tensor("ov::a", &va)
                .tensor("om::b", &mb)
                .tensor("ov::b", &vb)
                .scalar("step", t as f32)
                .scalar("lr", 5e-3);
            let mut out = be.run("gpt-nano", "recon_masklora_32x32", &feed).unwrap();
            let loss = out.scalar("loss");
            if t == 1 {
                first = loss;
            }
            last = loss;
            a = out.take("o::a");
            b = out.take("o::b");
            ma = out.take("om::a");
            va = out.take("ov::a");
            mb = out.take("om::b");
            vb = out.take("ov::b");
        }
        // a rank-4 adapter can only remove the top-4 singular directions of
        // the full-rank masked-out component (~10% of a random W's error), so
        // assert a real-but-bounded improvement rather than convergence
        assert!(
            last < 0.95 * first,
            "reconstruction should reduce loss: {first} -> {last}"
        );
    }

    #[test]
    fn calib_grams_are_symmetric_psd_diagonal() {
        let (be, params, masks) = nano_feed_state(4);
        let mm = be.model("gpt-nano").unwrap().clone();
        let b = mm.cfg.eval_batch;
        let s = mm.cfg.seq_len;
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> =
            (0..b * s).map(|_| rng.below(mm.cfg.vocab as u64) as i32).collect();
        let shape = [b, s];
        let mut feed = Feed::new().ints("tokens", &shape, &tokens);
        for (n, t) in params.iter().chain(masks.iter()) {
            feed = feed.owned_key(n.clone(), t);
        }
        let out = be.run("gpt-nano", "calib_stats", &feed).unwrap();
        assert_eq!(out.values.len(), mm.cfg.n_layers * 4);
        for (name, g) in &out.values {
            assert!(name.starts_with("gram::"), "{name}");
            let n = g.rows();
            for i in 0..n {
                assert!(g.at2(i, i) >= -1e-6, "{name}: negative diagonal");
                for j in 0..i {
                    assert!((g.at2(i, j) - g.at2(j, i)).abs() < 1e-2, "{name} not symmetric");
                }
            }
        }
    }

    #[test]
    fn pool_reuse_is_invisible_to_results() {
        let (be, params, masks) = nano_feed_state(11);
        let mm = be.model("gpt-nano").unwrap().clone();
        let b = mm.cfg.eval_batch;
        let s = mm.cfg.seq_len;
        let mut rng = Rng::new(12);
        let tokens: Vec<i32> =
            (0..b * s).map(|_| rng.below(mm.cfg.vocab as u64) as i32).collect();
        let shape = [b, s];
        let run = || {
            let mut feed = Feed::new().ints("tokens", &shape, &tokens);
            for (n, t) in params.iter().chain(masks.iter()) {
                feed = feed.owned_key(n.clone(), t);
            }
            be.run("gpt-nano", "eval_loss", &feed).unwrap().scalar("loss_sum")
        };
        let prev = pool::set_enabled(false);
        let cold = run();
        pool::set_enabled(true);
        let warm1 = run(); // populates the pool from its recycled tape
        let warm2 = run(); // runs on reused buffers
        let (hits, _) = pool::stats();
        assert!(hits > 0, "warm run should reuse tape buffers");
        assert_eq!(cold.to_bits(), warm1.to_bits(), "pooling must not change results");
        assert_eq!(cold.to_bits(), warm2.to_bits(), "reused buffers must be clean");
        pool::set_enabled(prev);
    }

    #[test]
    fn unknown_model_and_exec_error() {
        let be = NativeBackend::new();
        assert!(be.run("nope", "eval_loss", &Feed::new()).is_err());
        assert!(be.run("gpt-nano", "nope", &Feed::new()).is_err());
        assert!(be.prepare("gpt-nano", "nope").is_err());
        assert!(be.prepare("gpt-nano", "eval_loss").is_ok());
        assert_eq!(be.compiled_count(), 1);
        assert_eq!(be.exec_count(), 0);
    }
}
