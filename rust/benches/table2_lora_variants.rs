//! `cargo bench --bench table2_lora_variants` — regenerates the paper's table2
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("table2");
}
