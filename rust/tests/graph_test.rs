//! Integration tests for plan graphs: fork fan-out shares its prefix within
//! one run (asserted via executor exec counts), resumed graphs execute
//! nothing, seed replication is bitwise-identical to manual single-seed
//! runs, and the fork grammar round-trips through JSON.
//!
//! Shares the on-disk dense checkpoint cache with `pipeline_test.rs` /
//! `plan_test.rs` (same model / pretrain steps / data seed); each test
//! varies `retrain_steps` slightly so its *plan* stage keys never collide
//! with a concurrently running test.

use perp::config::ExperimentConfig;
use perp::pipeline::parse::parse_graph;
use perp::pipeline::{Executor, GraphBuilder, Plan};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{Backend, NativeBackend};

fn rt() -> NativeBackend {
    NativeBackend::new()
}

/// Same pretraining shape as pipeline_test.rs (shared dense checkpoint);
/// `retrain_steps` doubles as a per-test cache namespace.
fn cfg(retrain_steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 400;
    c.retrain_steps = retrain_steps;
    c.recon_steps = 6;
    c.calib_seqs = 8;
    c.items_per_task = 6;
    c.eval_batches = 2;
    c
}

fn cache_dir() -> std::path::PathBuf {
    std::env::temp_dir().join("perp_itest_cache")
}

#[test]
fn fork_executes_the_shared_prefix_once_per_run() {
    let rt = rt();
    let dir = cache_dir();
    let ex = Executor::new(&rt, cfg(21), dir.clone(), 0).quiet(true);
    let sparsities = [0.5, 0.7, 0.9];
    let g = GraphBuilder::new("fan")
        .pretrain()
        .fork_sparsities(Criterion::Magnitude, &sparsities)
        .eval_ppl()
        .build();

    // wipe this graph's exact stage dirs so the run is a full compute
    let probe = ex.run_graph(&g).unwrap();
    for nr in &probe.nodes {
        std::fs::remove_dir_all(dir.join("plan").join(&nr.rep.key)).ok();
    }

    let first = ex.run_graph(&g).unwrap();
    assert_eq!(first.nodes.len(), 1 + 3 + 3, "pretrain + 3 prunes + 3 evals");
    assert_eq!(first.computed(), 7, "wiped graph recomputes everything");
    // the fork's whole point: the shared pretrain prefix runs exactly once
    // even though three branches hang off it
    assert_eq!(first.computed_labeled("pretrain"), 1);
    assert_eq!(first.computed_labeled("prune"), 3);
    assert_eq!(first.computed_labeled("eval"), 3);

    // per-branch metrics exist and differ across sparsities
    let evals: Vec<f64> = first
        .nodes
        .iter()
        .filter_map(|n| n.rep.metrics.as_ref().map(|m| m.ppl))
        .collect();
    assert_eq!(evals.len(), 3);
    assert!(evals.iter().all(|p| p.is_finite()));

    // resume: zero computed nodes AND zero backend executions
    let execs_before = rt.exec_count();
    let second = ex.run_graph(&g).unwrap();
    assert_eq!(second.computed(), 0, "resumed graph loads every node");
    assert_eq!(
        rt.exec_count(),
        execs_before,
        "a resumed graph must not execute any backend graph"
    );
    for (a, b) in first.nodes.iter().zip(&second.nodes) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.rep.key, b.rep.key);
    }

    // key compatibility both ways: the equivalent linear plans hit the
    // graph-written cache entries unchanged (PR 3 chains == graph chains)
    for &sp in &sparsities {
        let plan = Plan::new("lin")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(sp))
            .eval_ppl();
        let rep = ex.run(&plan).unwrap();
        assert!(
            rep.stages.iter().all(|s| s.cache_hit),
            "linear plan at sparsity {sp} must hit the graph's cache: {rep:?}"
        );
    }
}

#[test]
fn seed_replication_matches_manual_single_seed_runs_bitwise() {
    let rt = rt();
    // fresh cache dirs: the graph must COMPUTE its replicas and the manual
    // runs must compute theirs — shared dirs would make the comparison a
    // trivial cache read-back
    let graph_dir = std::env::temp_dir().join("perp_graph_seed_test_graph");
    let manual_dir = std::env::temp_dir().join("perp_graph_seed_test_manual");
    std::fs::remove_dir_all(&graph_dir).ok();
    std::fs::remove_dir_all(&manual_dir).ok();

    let mut c = cfg(22);
    c.pretrain_steps = 120; // three pretrains below — keep the test cheap
    let g = GraphBuilder::new("seeded")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.6))
        .eval_ppl()
        .replicate_seeds(2)
        .aggregate("mean")
        .build();
    let ex = Executor::new(&rt, c.clone(), graph_dir.clone(), 0).quiet(true);
    let report = ex.run_graph(&g).unwrap();
    assert_eq!(report.nodes.len(), 6, "2 seeds × (pretrain|prune|eval)");

    // replica leaves in seed order
    let mut replica_ppl: Vec<(u64, f64)> = report
        .nodes
        .iter()
        .filter_map(|n| n.rep.metrics.as_ref().map(|m| (n.seed, m.ppl)))
        .collect();
    replica_ppl.sort_by_key(|(seed, _)| *seed);
    assert_eq!(replica_ppl.len(), 2);
    assert_eq!(replica_ppl[0].0, 0);
    assert_eq!(replica_ppl[1].0, 1);
    assert_ne!(
        replica_ppl[0].1, replica_ppl[1].1,
        "different seeds pretrain different weights"
    );

    // each replica is bitwise-identical to a manual single-seed linear run
    let plan = Plan::new("manual")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.6))
        .eval_ppl();
    for &(seed, graph_ppl) in &replica_ppl {
        let manual = Executor::new(&rt, c.clone(), manual_dir.clone(), seed)
            .quiet(true)
            .run(&plan)
            .unwrap();
        let manual_ppl = manual.last_metrics().expect("eval ran").ppl;
        assert!(
            graph_ppl == manual_ppl,
            "seed {seed}: replica ppl {graph_ppl} != manual ppl {manual_ppl}"
        );
    }

    // the aggregate row is the exact mean±std of the replica metrics
    let agg = report.aggregate("mean").expect("aggregate row");
    let want_mean = (replica_ppl[0].1 + replica_ppl[1].1) / 2.0;
    assert!((agg.ppl.mean - want_mean).abs() < 1e-12, "{} vs {want_mean}", agg.ppl.mean);
    assert_eq!(agg.ppl.n, 2);
    assert!(agg.ppl.std > 0.0);

    std::fs::remove_dir_all(&graph_dir).ok();
    std::fs::remove_dir_all(&manual_dir).ok();
}

#[test]
fn forked_branches_match_their_linear_equivalents() {
    // a fork after prune must produce the same metrics as running each
    // branch as its own linear plan — the snapshot at the fork point leaks
    // nothing between branches
    let rt = rt();
    let dir = cache_dir();
    let c = cfg(23);
    let ex = Executor::new(&rt, c.clone(), dir.clone(), 0).quiet(true);
    let g = parse_graph(
        "branchy",
        "prune(magnitude,0.5)|fork[eval(ppl);retrain(biases,9,0.001)|eval(ppl)]",
    )
    .unwrap();

    let probe = ex.run_graph(&g).unwrap();
    for nr in &probe.nodes {
        std::fs::remove_dir_all(dir.join("plan").join(&nr.rep.key)).ok();
    }
    let report = ex.run_graph(&g).unwrap();
    assert_eq!(report.computed_labeled("prune"), 1, "one prune feeds both branches");

    // fresh-dir linear equivalents
    let lin_dir = std::env::temp_dir().join("perp_graph_branch_test");
    std::fs::remove_dir_all(&lin_dir).ok();
    let lex = Executor::new(&rt, c, lin_dir.clone(), 0).quiet(true);
    let raw = lex
        .run(&Plan::new("raw")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .eval_ppl())
        .unwrap();
    let retrained = lex
        .run(&Plan::new("rt")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(perp::peft::Mode::Biases, Some(9), Some(0.001))
            .eval_ppl())
        .unwrap();

    let graph_ppls: Vec<f64> = report
        .nodes
        .iter()
        .filter_map(|n| n.rep.metrics.as_ref().map(|m| m.ppl))
        .collect();
    let raw_ppl = raw.last_metrics().unwrap().ppl;
    let rt_ppl = retrained.last_metrics().unwrap().ppl;
    assert!(
        graph_ppls.contains(&raw_ppl),
        "raw branch {graph_ppls:?} must contain linear {raw_ppl}"
    );
    assert!(
        graph_ppls.contains(&rt_ppl),
        "retrained branch {graph_ppls:?} must contain linear {rt_ppl}"
    );
    std::fs::remove_dir_all(&lin_dir).ok();
}

/// Every artifact file under two stage dirs is byte-identical.
fn assert_dir_bitwise_eq(a: &std::path::Path, b: &std::path::Path) {
    let names = |d: &std::path::Path| -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_dir(d)
            .unwrap_or_else(|e| panic!("reading {d:?}: {e}"))
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .collect();
        v.sort();
        v
    };
    let (na, nb) = (names(a), names(b));
    assert_eq!(na, nb, "artifact sets differ: {a:?} vs {b:?}");
    for n in &na {
        let fa = std::fs::read(a.join(n)).unwrap();
        let fb = std::fs::read(b.join(n)).unwrap();
        assert!(fa == fb, "artifact {n} differs between {a:?} and {b:?}");
    }
}

#[test]
fn parallel_run_matches_serial_bitwise_and_resumes_clean() {
    let rt = rt();
    // fresh separate caches: both the serial and the parallel run must
    // COMPUTE every node, or the comparison is a trivial cache read-back
    let ser_dir = std::env::temp_dir().join("perp_graph_par_test_serial");
    let par_dir = std::env::temp_dir().join("perp_graph_par_test_parallel");
    std::fs::remove_dir_all(&ser_dir).ok();
    std::fs::remove_dir_all(&par_dir).ok();

    let mut c = cfg(25);
    c.pretrain_steps = 120; // 2 seeds × 2 dirs = 4 pretrains — keep it cheap
    let g = GraphBuilder::new("par_fan")
        .pretrain()
        .fork_sparsities(Criterion::Magnitude, &[0.5, 0.8])
        .eval_ppl()
        .replicate_seeds(2)
        .aggregate("mean")
        .build();

    let serial = Executor::new(&rt, c.clone(), ser_dir.clone(), 0)
        .quiet(true)
        .jobs(1)
        .run_graph(&g)
        .unwrap();
    let parallel = Executor::new(&rt, c.clone(), par_dir.clone(), 0)
        .quiet(true)
        .jobs(4)
        .run_graph(&g)
        .unwrap();

    // both runs computed everything, sharing each seed's prefix once
    assert_eq!(serial.computed(), 2 * (1 + 2 + 2));
    assert_eq!(parallel.computed(), 2 * (1 + 2 + 2));
    assert_eq!(parallel.computed_labeled("pretrain"), 2, "one pretrain per seed");
    assert_eq!(parallel.computed_labeled("prune"), 4);

    // report order, keys and metrics are bitwise-identical to the serial
    // walk — completion order must never leak into the report
    assert_eq!(serial.nodes.len(), parallel.nodes.len());
    for (s, p) in serial.nodes.iter().zip(&parallel.nodes) {
        assert_eq!(s.name, p.name, "node order differs");
        assert_eq!(s.rep.key, p.rep.key);
        assert_eq!(s.seed, p.seed);
        match (&s.rep.metrics, &p.rep.metrics) {
            (Some(a), Some(b)) => {
                assert!(a.ppl == b.ppl, "{}: ppl {} != {}", s.name, a.ppl, b.ppl);
                assert!(a.loss == b.loss, "{}: loss differs", s.name);
                assert!(a.sparsity == b.sparsity, "{}: sparsity differs", s.name);
            }
            (None, None) => {}
            _ => panic!("{}: metrics presence differs", s.name),
        }
        // the artifacts themselves are byte-identical
        assert_dir_bitwise_eq(
            &ser_dir.join("plan").join(&s.rep.key),
            &par_dir.join("plan").join(&p.rep.key),
        );
    }

    // aggregate mean±std reduce identically
    let (sa, pa) = (
        serial.aggregate("mean").expect("serial aggregate"),
        parallel.aggregate("mean").expect("parallel aggregate"),
    );
    assert!(sa.ppl.mean == pa.ppl.mean && sa.ppl.std == pa.ppl.std);
    assert_eq!(sa.ppl.n, pa.ppl.n);
    assert!(sa.sparsity.mean == pa.sparsity.mean);

    // no staging leftovers: every .tmp-* dir was renamed into place
    for d in [&ser_dir, &par_dir] {
        let leftovers: Vec<String> = std::fs::read_dir(d.join("plan"))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .filter(|n| n.starts_with(".tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "staging dirs left behind in {d:?}: {leftovers:?}");
    }

    // resume after the parallel run: zero computed nodes, zero backend
    // executions, byte-stable report
    let execs_before = rt.exec_count();
    let resumed = Executor::new(&rt, c, par_dir.clone(), 0)
        .quiet(true)
        .jobs(4)
        .run_graph(&g)
        .unwrap();
    assert_eq!(resumed.computed(), 0, "resumed parallel graph loads every node");
    assert_eq!(rt.exec_count(), execs_before, "resume must not execute any backend graph");
    for (p, r) in parallel.nodes.iter().zip(&resumed.nodes) {
        assert_eq!(p.name, r.name);
        assert_eq!(p.rep.key, r.rep.key);
    }

    std::fs::remove_dir_all(&ser_dir).ok();
    std::fs::remove_dir_all(&par_dir).ok();
}

#[test]
fn branches_sharing_a_stage_key_execute_it_once_under_parallelism() {
    // two fork branches with IDENTICAL chains: their nodes are distinct but
    // content-address to the same keys, so the in-flight dedup must run
    // each stage once — the second branch waits, then reads a cache hit
    let rt = rt();
    let dir = cache_dir();
    let ex = Executor::new(&rt, cfg(24), dir.clone(), 0).quiet(true).jobs(2);
    let g = parse_graph(
        "dup",
        "fork[prune(magnitude,0.5)|eval(ppl);prune(magnitude,0.5)|eval(ppl)]",
    )
    .unwrap();

    // wipe this graph's exact stage dirs so the run is a full compute
    let probe = ex.run_graph(&g).unwrap();
    for nr in &probe.nodes {
        std::fs::remove_dir_all(dir.join("plan").join(&nr.rep.key)).ok();
    }

    let report = ex.run_graph(&g).unwrap();
    assert_eq!(report.nodes.len(), 5, "pretrain + 2 prunes + 2 evals");
    assert_eq!(report.computed_labeled("pretrain"), 1);
    assert_eq!(report.computed_labeled("prune"), 1, "duplicate chains share one prune");
    assert_eq!(report.computed_labeled("eval"), 1, "duplicate chains share one eval");
    // the twin branches carry the same keys and the same metrics
    let evals: Vec<(&str, f64)> = report
        .nodes
        .iter()
        .filter_map(|n| n.rep.metrics.as_ref().map(|m| (n.rep.key.as_str(), m.ppl)))
        .collect();
    assert_eq!(evals.len(), 2);
    assert_eq!(evals[0].0, evals[1].0);
    assert!(evals[0].1 == evals[1].1);
}

#[test]
fn fork_grammar_roundtrips_and_validates() {
    let g = parse_graph(
        "rt",
        "fork[prune(magnitude,0.5);prune(wanda,0.7)]|retrain(masklora,5)|merge|eval(ppl)|seeds(2)|agg",
    )
    .unwrap();
    g.validate().unwrap();
    // 2 seeds × (pretrain + 2×(prune+retrain+merge+eval))
    assert_eq!(g.stage_count(), 2 * (1 + 2 * 4));
    assert_eq!(g.roots().len(), 2);
    assert_eq!(g.leaves().len(), 4);

    let text = g.to_string_pretty();
    let g2 = perp::pipeline::PlanGraph::from_text(&text).unwrap();
    assert_eq!(g, g2, "graph JSON round-trip must be lossless");
    g2.validate().unwrap();

    // the aggregate reduces all four seed-replicated eval leaves
    let agg = g
        .nodes
        .iter()
        .find_map(|n| match &n.kind {
            perp::pipeline::NodeKind::Aggregate { over } => Some(over.clone()),
            _ => None,
        })
        .expect("aggregate node");
    assert_eq!(agg.len(), 4);
}
