//! Micro/macro benchmark harness (criterion replacement).
//!
//! Warmup + timed iterations with mean/p50/p95 reporting and a markdown table
//! writer, so every `cargo bench` target regenerates its paper table in the
//! same format EXPERIMENTS.md records.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

pub struct Bench {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 3,
            min_iters: 5,
            max_iters: 200,
            target: Duration::from_secs(2),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: 1, min_iters: 3, max_iters: 20, target: Duration::from_millis(500) }
    }

    /// Time `f` until the time budget or max_iters is reached.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.target && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        Stats {
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
            min: samples[0],
        }
    }
}

/// Markdown table accumulator used by every bench target and sweep.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }

    /// Append the table to a results file (used to accumulate bench output).
    pub fn append_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(self.to_markdown().as_bytes())
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench { warmup: 1, min_iters: 4, max_iters: 8, target: Duration::from_millis(5) };
        let mut n = 0u64;
        let stats = b.run(|| {
            n = n.wrapping_add(1);
            std::hint::black_box(n);
        });
        assert!(stats.iters >= 4);
        assert!(stats.min <= stats.p50 && stats.p50 <= stats.p95);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert_eq!(md.lines().filter(|l| l.starts_with('|')).count(), 3);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.0µs");
    }
}
