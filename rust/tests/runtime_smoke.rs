//! End-to-end smoke of the AOT bridge: rust-initialised params through the
//! compiled `eval_loss` / `train_biases` graphs on the PJRT CPU client.
//!
//! Requires `make artifacts` (gpt-nano) — the tests fail loudly otherwise.

use std::collections::BTreeMap;

use perp::model::{init, ParamStore};
use perp::runtime::{default_artifacts_dir, Feed, Runtime};
use perp::tensor::Tensor;
use perp::util::rng::Rng;

fn ones_masks(mm: &perp::runtime::ModelManifest) -> BTreeMap<String, Tensor> {
    mm.prunable
        .iter()
        .map(|n| (n.clone(), Tensor::ones(mm.param_shape(n))))
        .collect()
}

fn feed_params<'a>(
    feed: Feed<'a>,
    ps: &'a ParamStore,
    masks: &'a BTreeMap<String, Tensor>,
) -> Feed<'a> {
    let mut f = feed;
    for (name, t) in ps.map() {
        // the manifest names params `p::<name>` — cheap to pre-insert all
        f = f.owned(&format!("p::{name}"), t.clone());
    }
    for (name, t) in masks {
        f = f.owned(&format!("m::{name}"), t.clone());
    }
    f
}

#[test]
fn eval_loss_near_uniform_at_init() {
    let rt = Runtime::new(&default_artifacts_dir()).expect("make artifacts first");
    let mm = rt.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(0);
    let ps = init::init_params(&mm, &mut rng);
    let masks = ones_masks(&mm);

    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let tokens: Vec<i32> = (0..b * s)
        .map(|_| rng.below(mm.cfg.vocab as u64) as i32)
        .collect();
    let shape = [b, s];
    let feed = feed_params(Feed::new(), &ps, &masks).ints("tokens", &shape, &tokens);
    let out = rt.run("gpt-nano", "eval_loss", &feed).unwrap();
    let loss = out.scalar("loss_sum") / out.scalar("count");
    let uniform = (mm.cfg.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.6,
        "init loss {loss} should be near log(V)={uniform}"
    );
}

#[test]
fn train_biases_step_updates_only_biases() {
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(1);
    let ps = init::init_params(&mm, &mut rng);
    let masks = ones_masks(&mm);
    let trainables = mm.trainable.get("biases").unwrap().clone();
    assert!(!trainables.is_empty());

    let b = mm.cfg.train_batch;
    let s = mm.cfg.seq_len;
    let tokens: Vec<i32> = (0..b * s)
        .map(|_| rng.below(mm.cfg.vocab as u64) as i32)
        .collect();
    let shape = [b, s];

    let mut feed = feed_params(Feed::new(), &ps, &masks)
        .ints("tokens", &shape, &tokens)
        .scalar("step", 1.0)
        .scalar("lr", 0.1);
    for n in &trainables {
        feed = feed
            .owned(&format!("om::{n}"), Tensor::zeros(mm.param_shape(n)))
            .owned(&format!("ov::{n}"), Tensor::zeros(mm.param_shape(n)));
    }
    let mut out = rt.run("gpt-nano", "train_biases", &feed).unwrap();
    let loss = out.scalar("loss");
    assert!(loss.is_finite() && loss > 0.0);

    // updated biases differ from the zero init; moments became nonzero
    let updated = out.drain_prefix("o::");
    assert_eq!(updated.len(), trainables.len());
    let mut any_moved = false;
    for (name, t) in &updated {
        assert_eq!(t.shape(), mm.param_shape(name));
        if t.max_abs() > 0.0 {
            any_moved = true;
        }
    }
    assert!(any_moved, "no bias moved after one step");
}

#[test]
fn executable_cache_compiles_once() {
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let a = rt.load("gpt-nano", "eval_loss").unwrap();
    let b = rt.load("gpt-nano", "eval_loss").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn feed_shape_mismatch_is_detected() {
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let mm = rt.model("gpt-nano").unwrap().clone();
    let ps = ParamStore::zeros(&mm);
    let masks = ones_masks(&mm);
    let tokens = vec![0i32; 4]; // wrong shape
    let shape = [2usize, 2];
    let feed = feed_params(Feed::new(), &ps, &masks).ints("tokens", &shape, &tokens);
    let err = rt.run("gpt-nano", "eval_loss", &feed);
    assert!(err.is_err());
}
