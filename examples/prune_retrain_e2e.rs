//! End-to-end system driver (the EXPERIMENTS.md §E2E run).
//!
//! Exercises every layer on a real workload: train a GPT from scratch on the
//! synthetic corpus for a few hundred steps (loss curve logged), evaluate
//! perplexity + the seven-task zero-shot suite, magnitude-prune, retrain with
//! each headline PERP method, and verify the MaskLoRA merge invariant — all
//! through the pluggable execution backend (native by default); no Python
//! anywhere.
//!
//! ```bash
//! cargo run --release --offline --example prune_retrain_e2e -- \
//!     [--model gpt-small] [--steps 400] [--retrain-steps 200] [--sparsity 0.5]
//! ```

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::ExpContext;
use perp::coordinator::Session;
use perp::peft::Mode;
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{open_default_backend, Backend};
use perp::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).map_err(|e| anyhow::anyhow!(e))?;
    let model = args.str("model", "gpt-small");
    let steps = args.u64("steps", 400)?;
    let retrain_steps = args.u64("retrain-steps", 200)?;
    let pattern = Pattern::parse(&args.str("sparsity", "0.5")).map_err(|e| anyhow::anyhow!(e))?;
    args.finish()?;

    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::full(&model);
    cfg.pretrain_steps = steps;
    cfg.retrain_steps = retrain_steps;
    cfg.items_per_task = 25;

    let mm = rt.model(&model)?.clone();
    println!(
        "== e2e: {} ({} params, d={}, L={}, V={}) ==",
        model,
        mm.total_params(),
        mm.cfg.d_model,
        mm.cfg.n_layers,
        mm.cfg.vocab
    );

    // ---- 1. pretraining with a logged loss curve -------------------------
    let mut s = Session::new(rt.as_ref(), cfg.clone(), 0)?;
    let t0 = std::time::Instant::now();
    s.pretrain(steps, cfg.pretrain_lr)?;
    let train_secs = t0.elapsed().as_secs_f64();
    println!("\n-- loss curve ({} steps, {:.0} tok/s) --", steps, s.last_tps);
    let losses = s.last_losses.clone();
    let stride = (losses.len() / 16).max(1);
    for (i, chunk) in losses.chunks(stride).enumerate() {
        let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  step {:>5}: loss {:.4}", i * stride + 1, mean);
    }
    println!(
        "  trained {} tokens in {:.1}s",
        steps * (mm.cfg.train_batch * mm.cfg.seq_len) as u64,
        train_secs
    );

    let dense_ppl = s.eval_ppl_test()?;
    let dense_tasks = s.eval_tasks()?;
    let dense_acc = perp::eval::mean_accuracy(&dense_tasks);
    println!(
        "\ndense: test ppl {:.2}, zero-shot acc {:.1}%",
        dense_ppl.ppl,
        dense_acc * 100.0
    );
    for t in &dense_tasks {
        println!("   {:>6}: {:.1}%", t.name, t.accuracy * 100.0);
    }

    // ---- 2. prune --------------------------------------------------------
    let ctx = ExpContext::new(rt.as_ref(), cfg.clone(), "results/cache".into());
    let mut base = ctx.clone_session(&s)?;
    base.prune(Criterion::Magnitude, pattern, None)?;
    let pruned_ppl = base.eval_ppl_test()?;
    println!(
        "\npruned magnitude @ {}: ppl {:.2} (x{:.2}), sparsity {:.3}",
        pattern.label(),
        pruned_ppl.ppl,
        pruned_ppl.ppl / dense_ppl.ppl,
        base.masks.sparsity()
    );

    // ---- 3. retrain with each headline method ----------------------------
    println!("\n-- retraining ({retrain_steps} steps each) --");
    println!(
        "{:<22} {:>12} {:>10} {:>10} {:>12}",
        "method", "trainable%", "ppl", "acc", "tok/s"
    );
    for mode in [Mode::Biases, Mode::Ln, Mode::MaskLora, Mode::ScaleLora, Mode::Full] {
        if mode == Mode::Biases && base.mm.trainable_count("biases") == 0 {
            continue;
        }
        let mut r = ctx.clone_session(&base)?;
        r.retrain(mode, retrain_steps, cfg.lr_grid[0])?;
        r.merge_adapters()?;
        let ppl = r.eval_ppl_test()?;
        let acc = perp::eval::mean_accuracy(&r.eval_tasks()?);
        let pct = 100.0 * r.mm.trainable_count(mode.trainable_key()) as f64
            / r.mm.total_params() as f64;
        // merge invariant: sparsity survives retraining end-to-end
        let sparsity = r.params.weight_sparsity(&r.mm);
        assert!(
            mode == Mode::Lora || (sparsity - base.masks.sparsity()).abs() < 1e-6,
            "sparsity lost: {sparsity}"
        );
        println!(
            "{:<22} {:>11.3}% {:>10.2} {:>9.1}% {:>12.0}",
            mode.name(),
            pct,
            ppl.ppl,
            acc * 100.0,
            r.last_tps
        );
    }

    println!("\ne2e complete: all layers composed on the {} backend.", rt.kind());
    Ok(())
}
