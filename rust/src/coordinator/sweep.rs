//! Experiment registry: every paper table/figure as a [`PlanGraph`]
//! generator over the shared pipeline executor, emitting markdown tables
//! (EXPERIMENTS.md records them).
//!
//! | exp id   | paper artifact       | shape reproduced                          |
//! |----------|----------------------|-------------------------------------------|
//! | fig1     | Fig 1/3/4            | ppl+acc vs sparsity per retrained subset  |
//! | table1   | Table 1/7/8          | subsets vs full FT across sparsities      |
//! | table2   | Table 2/9–14         | LoRA variants × {50%, 2:4, 4:8}           |
//! | fig2     | Fig 2                | MaskLoRA ppl vs retrain iterations        |
//! | table3   | Table 3/24           | per-task Δacc from MaskLoRA retraining    |
//! | table4   | Table 4              | retraining throughput (tps)               |
//! | table5   | Table 5/15–18        | recon on/off × pruner × pattern           |
//! | table19  | Table 19             | MaskLoRA vs full-FT reconstruction        |
//! | table20  | Tables 20/21         | subset-combination ablation               |
//! | table22  | Tables 22/23         | high-sparsity recon vs retrain            |
//! | memory   | §3.2 efficiency      | analytical 30B-on-one-A100 table          |
//!
//! Every cell is a named node in one graph per table, executed through
//! [`crate::pipeline::Executor::run_graph`].  Consequences:
//!
//! * shared prefixes execute **once per run** — one pretrain per table, one
//!   prune per (criterion, sparsity) regardless of how many retrain modes
//!   or strategies hang off it (the old bespoke `pruned_session` +
//!   `clone_session` plumbing per table is gone);
//! * every cell is content-addressed, so re-running a sweep only computes
//!   cells whose chains changed, and one-off `repro run` invocations hit
//!   the very same artifacts;
//! * `table22` aggregates mean±std across `cfg.seeds` when the profile
//!   carries more than one seed (seed-replicated subgraphs + `Aggregate`
//!   nodes).
//!
//! [`ExpContext`] remains the session-level toolkit (dense checkpoint
//! cache, cloning, evaluation) used by the executor itself, the examples
//! and the integration tests.  Two deliberate exceptions stay on the
//! session path: `table4` times its retrains live (throughput is a
//! measurement, not a cacheable artifact — only its pretrain|prune prefix
//! goes through the executor), and `table20`'s optional `combo_*`
//! executables are not part of the [`Stage`] vocabulary.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::reconstruct::ReconMode;
use crate::coordinator::Session;
use crate::peft::Mode;
use crate::pipeline::{Executor, GraphReport, Plan, PlanGraph, Stage};
use crate::pruning::{Criterion, Pattern};
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::bench::Table;

pub const EXPERIMENTS: [&str; 11] = [
    "fig1", "table1", "table2", "fig2", "table3", "table4", "table5",
    "table19", "table20", "table22", "memory",
];

pub struct ExpContext<'rt> {
    pub rt: &'rt dyn Backend,
    pub cfg: ExperimentConfig,
    pub cache_dir: PathBuf,
    /// concurrent plan-graph nodes for every sweep this context drives
    /// (`--jobs`/`PERP_JOBS`; 1 = serial walk)
    pub jobs: usize,
}

#[derive(Debug, Clone, Default)]
pub struct CellResult {
    pub ppl: f64,
    pub acc: f64,
    pub per_task: Vec<(String, f64)>,
    pub tps: f64,
    pub trainable_pct: f64,
}

impl<'rt> ExpContext<'rt> {
    pub fn new(rt: &'rt dyn Backend, cfg: ExperimentConfig, cache_dir: PathBuf) -> Self {
        ExpContext { rt, cfg, cache_dir, jobs: 1 }
    }

    /// Set the plan-graph worker count for every sweep run through this
    /// context (builder-style, like the executor's own `jobs`).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// A session holding converged dense weights (cached on disk).  The key
    /// covers everything pretraining reads — model, seed, steps, lr, data
    /// seed and backend — so a stale checkpoint can never satisfy a changed
    /// config (the plan executor relies on this).
    pub fn dense_session(&self, seed: u64) -> Result<Session<'rt>> {
        let mut s = Session::new(self.rt, self.cfg.clone(), seed)?;
        let key = format!(
            "{}-s{}-p{}-lr{}-d{}-{}.ptns",
            self.cfg.model,
            seed,
            self.cfg.pretrain_steps,
            self.cfg.pretrain_lr,
            self.cfg.data_seed,
            self.cfg.backend,
        );
        let path = self.cache_dir.join(key);
        if path.exists() {
            s.load(&path)?;
        } else {
            crate::info!(
                "pretraining {} for {} steps (cache miss)",
                self.cfg.model,
                self.cfg.pretrain_steps
            );
            s.pretrain(self.cfg.pretrain_steps, self.cfg.pretrain_lr)?;
            std::fs::create_dir_all(&self.cache_dir).ok();
            s.save(&path)?;
        }
        Ok(s)
    }

    /// Dense → calibrate (if needed) → prune.  Returns the session plus the
    /// dense weight snapshot (reconstruction targets).  The sweeps now fork
    /// graphs instead, but the examples and integration tests still build
    /// one-off pruned sessions with this.
    pub fn pruned_session(
        &self,
        seed: u64,
        criterion: Criterion,
        pattern: Pattern,
    ) -> Result<(Session<'rt>, BTreeMap<String, Tensor>)> {
        let mut s = self.dense_session(seed)?;
        let dense: BTreeMap<String, Tensor> = s
            .mm
            .prunable
            .iter()
            .map(|n| (n.clone(), s.params.get(n).clone()))
            .collect();
        let grams = if criterion.needs_calibration() {
            Some(s.calibrate()?)
        } else {
            None
        };
        s.prune(criterion, pattern, grams.as_ref())?;
        Ok((s, dense))
    }

    /// Retrain with the best LR from the grid (tuned on val ppl, like the
    /// paper).  Returns the best cell plus the chosen lr.
    pub fn retrain_tuned(
        &self,
        base: &Session<'rt>,
        mode: Mode,
        steps: u64,
        with_tasks: bool,
    ) -> Result<(CellResult, f64)> {
        let mut best: Option<(CellResult, f64)> = None;
        for &lr in &self.cfg.lr_grid {
            let mut s = self.clone_session(base)?;
            s.retrain(mode, steps, lr)?;
            if mode != Mode::Lora {
                // standard LoRA stays unmerged (Table 2's "Mergeable: no")
                s.merge_adapters()?;
            }
            let cell = self.evaluate(&mut s, with_tasks, Some(mode))?;
            if best.as_ref().map(|(b, _)| cell.ppl < b.ppl).unwrap_or(true) {
                best = Some((cell, lr));
            }
        }
        Ok(best.expect("non-empty lr grid"))
    }

    /// Clone a session's mutable state into a fresh session (shares nothing).
    pub fn clone_session(&self, base: &Session<'rt>) -> Result<Session<'rt>> {
        let mut s = Session::new(self.rt, self.cfg.clone(), 0)?;
        s.params = base.params.clone();
        s.masks = base.masks.clone();
        s.refresh_sparse();
        Ok(s)
    }

    pub fn evaluate(
        &self,
        s: &mut Session<'rt>,
        with_tasks: bool,
        mode: Option<Mode>,
    ) -> Result<CellResult> {
        let ppl = s.eval_ppl_test()?;
        let (acc, per_task) = if with_tasks {
            let tr = s.eval_tasks()?;
            (
                crate::eval::mean_accuracy(&tr),
                tr.into_iter().map(|t| (t.name, t.accuracy)).collect(),
            )
        } else {
            (f64::NAN, Vec::new())
        };
        let trainable_pct = mode
            .map(|m| {
                let key = m.trainable_key();
                100.0 * s.mm.trainable_count(key) as f64 / s.mm.total_params() as f64
            })
            .unwrap_or(0.0);
        Ok(CellResult {
            ppl: ppl.ppl,
            acc,
            per_task,
            tps: s.last_tps,
            trainable_pct,
        })
    }

    /// The graph executor every table runs through (quiet — tables narrate
    /// through their rows, not per-stage progress lines).
    fn executor(&self) -> Executor<'rt> {
        Executor::new(
            self.rt,
            self.cfg.clone(),
            self.cache_dir.clone(),
            self.cfg.seeds[0],
        )
        .quiet(true)
        .jobs(self.jobs)
    }
}

fn fmt_ppl(p: f64) -> String {
    if p.is_nan() {
        "-".into()
    } else if p > 1000.0 {
        format!("{p:.0}")
    } else {
        format!("{p:.2}")
    }
}

fn fmt_acc(a: f64) -> String {
    if a.is_nan() {
        "-".into()
    } else {
        format!("{:.1}%", a * 100.0)
    }
}

// ---------------------------------------------------------------------------
// Graph-building vocabulary shared by the tables.
// ---------------------------------------------------------------------------

fn prune_stage(criterion: Criterion, pattern: Pattern) -> Stage {
    Stage::Prune { criterion, pattern }
}

fn eval_stage(tasks: bool) -> Stage {
    Stage::Eval { tasks }
}

/// Attach `retrain [→ merge] → eval` under `parent`; returns the names of
/// the (retrain, eval) nodes.  Standard LoRA evaluates unmerged (Table 2's
/// "Mergeable: no"); every other LoRA variant merges first.
fn retrain_cell(
    g: &mut PlanGraph,
    parent: &str,
    cell: &str,
    mode: Mode,
    steps: Option<u64>,
    lr: Option<f64>,
    tasks: bool,
) -> (String, String) {
    let retrain = format!("{cell}:retrain");
    g.stage_node(&retrain, Some(parent), Stage::Retrain { mode, steps, lr });
    let mut tail = retrain.clone();
    if mode.is_lora() && mode != Mode::Lora {
        let merge = format!("{cell}:merge");
        g.stage_node(&merge, Some(&tail), Stage::Merge);
        tail = merge;
    }
    let eval = format!("{cell}:eval");
    g.stage_node(&eval, Some(&tail), eval_stage(tasks));
    (retrain, eval)
}

/// Metrics accessor with a uniform error for cells that went missing.
fn cell_metrics<'a>(
    report: &'a GraphReport,
    name: &str,
) -> Result<&'a crate::pipeline::EvalMetrics> {
    report
        .metrics(name)
        .with_context(|| format!("sweep graph produced no metrics for cell {name:?}"))
}

/// Entry point: run one experiment id, return its tables.
pub fn run(ctx: &ExpContext, exp: &str) -> Result<Vec<Table>> {
    let _sp = crate::span!("sweep", "exp {exp}").arg("jobs", ctx.jobs);
    match exp {
        "fig1" => fig1(ctx),
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "fig2" => fig2(ctx),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "table5" => table5(ctx),
        "table19" => table19(ctx),
        "table20" => table20(ctx),
        "table22" => table22(ctx),
        "memory" => memory(ctx),
        other => bail!("unknown experiment {other:?}; known: {EXPERIMENTS:?}"),
    }
}

const SPARSITIES: [f64; 5] = [0.3, 0.4, 0.5, 0.6, 0.7];

/// Fig 1/3/4 + Table 1 share this engine: one graph with a single pretrain
/// root, one prune node per sparsity, and one retrain branch per mode under
/// each prune — the fan the paper's figures sweep.
fn subset_sweep(ctx: &ExpContext, modes: &[Option<Mode>], title: &str) -> Result<Vec<Table>> {
    let mut g = PlanGraph::new("subset-sweep");
    g.stage_node("pre", None, Stage::Pretrain);
    g.stage_node("dense:eval", Some("pre"), eval_stage(true));
    for &sp in &SPARSITIES {
        let prune = format!("prune@{sp}");
        g.stage_node(
            &prune,
            Some("pre"),
            prune_stage(Criterion::Magnitude, Pattern::Unstructured(sp)),
        );
        for mode in modes {
            match mode {
                None => {
                    g.stage_node(&format!("none@{sp}:eval"), Some(&prune), eval_stage(true));
                }
                Some(m) => {
                    let cell = format!("{}@{sp}", m.name());
                    retrain_cell(&mut g, &prune, &cell, *m, None, None, true);
                }
            }
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let dense = cell_metrics(&report, "dense:eval")?;
    let mut headers = vec!["Method".to_string(), "% trainable".to_string()];
    headers.extend(SPARSITIES.iter().map(|s| format!("{:.0}%", s * 100.0)));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut ppl_t = Table::new(&format!("{title} — perplexity (dense {:.2})", dense.ppl), &hdr);
    let mut acc_t = Table::new(&format!("{title} — zero-shot acc (dense {})", fmt_acc(dense.acc)), &hdr);

    for mode in modes {
        let name = mode.map(|m| m.name().to_string()).unwrap_or("none".into());
        let mut ppl_row = Vec::new();
        let mut acc_row = Vec::new();
        let mut pct = 0.0;
        for &sp in &SPARSITIES {
            let m = cell_metrics(&report, &format!("{name}@{sp}:eval"))?;
            if let Some(mode) = mode {
                pct = report
                    .node(&format!("{}@{sp}:retrain", mode.name()))
                    .and_then(|r| r.trainable_pct)
                    .unwrap_or(pct);
            }
            ppl_row.push(fmt_ppl(m.ppl));
            acc_row.push(fmt_acc(m.acc));
        }
        let mut r1 = vec![name.clone(), format!("{pct:.3}%")];
        r1.extend(ppl_row);
        ppl_t.row(r1);
        let mut r2 = vec![name, format!("{pct:.3}%")];
        r2.extend(acc_row);
        acc_t.row(r2);
    }
    Ok(vec![ppl_t, acc_t])
}

fn fig1(ctx: &ExpContext) -> Result<Vec<Table>> {
    subset_sweep(
        ctx,
        &[
            None,
            Some(Mode::Head),
            Some(Mode::Embed),
            Some(Mode::Biases),
            Some(Mode::Ln),
            Some(Mode::Full),
        ],
        "Fig 1/3/4: subset retraining vs sparsity (magnitude pruning)",
    )
}

fn table1(ctx: &ExpContext) -> Result<Vec<Table>> {
    let mut modes: Vec<Option<Mode>> = vec![
        Some(Mode::Full),
        Some(Mode::MaskLora),
        Some(Mode::Biases),
        Some(Mode::Ln),
        None,
    ];
    // LLaMA-style models have no biases (Table 8)
    if ctx.rt.model(&ctx.cfg.model)?.trainable_count("biases") == 0 {
        modes.retain(|m| *m != Some(Mode::Biases));
    }
    subset_sweep(ctx, &modes, "Table 1/7/8: PERP vs full retraining")
}

fn patterns_for_table2() -> Vec<Pattern> {
    vec![
        Pattern::Unstructured(0.5),
        Pattern::SemiStructured { n: 2, m: 4 },
        Pattern::SemiStructured { n: 4, m: 8 },
    ]
}

fn table2(ctx: &ExpContext) -> Result<Vec<Table>> {
    let mut g = PlanGraph::new("table2");
    g.stage_node("pre", None, Stage::Pretrain);
    g.stage_node("dense:eval", Some("pre"), eval_stage(true));
    for pattern in patterns_for_table2() {
        let prune = format!("prune@{}", pattern.label());
        g.stage_node(&prune, Some("pre"), prune_stage(Criterion::Magnitude, pattern));
        for mode in Mode::ALL_LORA {
            retrain_cell(
                &mut g,
                &prune,
                &format!("{}@{}", mode.name(), pattern.label()),
                mode,
                None,
                None,
                true,
            );
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let hdr = ["Method", "Mergeable", "Sparsity", "Perplexity", "Accuracy"];
    let mut t = Table::new("Table 2/9-14: LoRA variants (magnitude pruning)", &hdr);
    let d = cell_metrics(&report, "dense:eval")?;
    t.row(vec![
        "baseline".into(), "-".into(), "0%".into(), fmt_ppl(d.ppl), fmt_acc(d.acc),
    ]);
    for pattern in patterns_for_table2() {
        for mode in Mode::ALL_LORA {
            let m = cell_metrics(&report, &format!("{}@{}:eval", mode.name(), pattern.label()))?;
            let mergeable = match mode.mergeable_sparsity_preserving() {
                Some(true) => "yes",
                Some(false) => "no",
                None => "-",
            };
            t.row(vec![
                mode.name().into(),
                mergeable.into(),
                pattern.label(),
                fmt_ppl(m.ppl),
                fmt_acc(m.acc),
            ]);
        }
    }
    Ok(vec![t])
}

fn fig2(ctx: &ExpContext) -> Result<Vec<Table>> {
    let iters: Vec<u64> = [0u64, 5, 15, 50, 150, 300]
        .into_iter()
        .filter(|&i| i <= ctx.cfg.retrain_steps.max(30) * 3)
        .collect();
    let sparsities = [0.4, 0.5, 0.6, 0.7];
    let lr = ctx.cfg.lr_grid[0];

    let mut g = PlanGraph::new("fig2");
    g.stage_node("pre", None, Stage::Pretrain);
    for &sp in &sparsities {
        let prune = format!("prune@{sp}");
        g.stage_node(
            &prune,
            Some("pre"),
            prune_stage(Criterion::Magnitude, Pattern::Unstructured(sp)),
        );
        for &it in &iters {
            if it == 0 {
                g.stage_node(&format!("it0@{sp}:eval"), Some(&prune), eval_stage(false));
            } else {
                retrain_cell(
                    &mut g,
                    &prune,
                    &format!("it{it}@{sp}"),
                    Mode::MaskLora,
                    Some(it),
                    Some(lr),
                    false,
                );
            }
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let mut headers = vec!["Sparsity".to_string()];
    headers.extend(iters.iter().map(|i| format!("it {i}")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 2: MaskLoRA perplexity vs retraining iterations", &hdr);
    for &sp in &sparsities {
        let mut row = vec![format!("{:.0}%", sp * 100.0)];
        for &it in &iters {
            let m = cell_metrics(&report, &format!("it{it}@{sp}:eval"))?;
            row.push(fmt_ppl(m.ppl));
        }
        t.row(row);
    }
    Ok(vec![t])
}

fn table3(ctx: &ExpContext) -> Result<Vec<Table>> {
    let sparsities = [0.5, 0.6, 0.7];
    let criteria = [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt];

    // the DAG advantage in miniature: each (criterion, sparsity) prune is
    // evaluated twice — raw, and after retraining — off one shared node
    let mut g = PlanGraph::new("table3");
    g.stage_node("pre", None, Stage::Pretrain);
    for &sp in &sparsities {
        for crit in criteria {
            let cell = format!("{}@{sp}", crit.name());
            let prune = format!("{cell}:prune");
            g.stage_node(&prune, Some("pre"), prune_stage(crit, Pattern::Unstructured(sp)));
            g.stage_node(&format!("{cell}:before"), Some(&prune), eval_stage(true));
            let retrain = format!("{cell}:retrain");
            g.stage_node(&retrain, Some(&prune), Stage::Retrain {
                mode: Mode::MaskLora,
                steps: None,
                lr: None,
            });
            let merge = format!("{cell}:merge");
            g.stage_node(&merge, Some(&retrain), Stage::Merge);
            g.stage_node(&format!("{cell}:after"), Some(&merge), eval_stage(true));
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let mut headers = vec!["Method".to_string(), "Sparsity".to_string()];
    headers.extend(crate::data::tasks::TASK_NAMES.iter().map(|s| s.to_string()));
    headers.push("Average".to_string());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 3/24: Δ zero-shot accuracy from MaskLoRA retraining",
        &hdr,
    );
    for &sp in &sparsities {
        for crit in criteria {
            let cell = format!("{}@{sp}", crit.name());
            let before = cell_metrics(&report, &format!("{cell}:before"))?;
            let after = cell_metrics(&report, &format!("{cell}:after"))?;
            let mut row = vec![crit.name().to_string(), format!("{:.0}%", sp * 100.0)];
            let b: BTreeMap<_, _> = before.per_task.iter().cloned().collect();
            let mut deltas = Vec::new();
            for (name, acc) in &after.per_task {
                let d = acc - b.get(name).copied().unwrap_or(0.0);
                deltas.push(d);
                row.push(format!("{:+.1}%", d * 100.0));
            }
            let avg = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
            row.push(format!("{:+.1}%", avg * 100.0));
            t.row(row);
        }
    }
    Ok(vec![t])
}

fn table4(ctx: &ExpContext) -> Result<Vec<Table>> {
    let steps = ctx.cfg.retrain_steps.min(30).max(10);
    let modes = [
        Mode::Full,
        Mode::Lora,
        Mode::ScaleLora,
        Mode::MaskLoraStd,
        Mode::MaskLora,
        Mode::BiasesLn,
    ];
    // throughput is a *measurement*, not a cacheable artifact: the shared
    // pretrain|prune prefix runs through the executor (and its cache), but
    // each mode is timed live on a fresh clone so the reported tokens/s is
    // never a stale cached number
    let prefix = Plan::new("table4-prefix")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.5));
    let (_, base) = ctx.executor().run_with_session(&prefix)?;

    let hdr = ["Method", "% trainable", "tokens/s", "relative"];
    let mut t = Table::new("Table 4: retraining throughput", &hdr);
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for mode in modes {
        let mut s = ctx.clone_session(&base)?;
        // warmup pass: compiles the executable + faults in caches so the
        // measured pass is steady-state (paper reports steady-state tps)
        s.retrain(mode, 3, ctx.cfg.lr_grid[0])?;
        s.retrain(mode, steps, ctx.cfg.lr_grid[0])?;
        let pct = 100.0 * s.mm.trainable_count(mode.trainable_key()) as f64
            / s.mm.total_params() as f64;
        let label = match mode {
            Mode::MaskLora => "masklora (optimized)".to_string(),
            Mode::MaskLoraStd => "masklora (standard)".to_string(),
            m => m.name().to_string(),
        };
        rows.push((label, pct, s.last_tps));
    }
    let full_tps = rows[0].2;
    for (name, pct, tps) in rows {
        t.row(vec![
            name,
            format!("{pct:.3}%"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / full_tps.max(1e-9)),
        ]);
    }
    Ok(vec![t])
}

fn recon_sweep(
    ctx: &ExpContext,
    patterns: &[Pattern],
    criteria: &[Criterion],
    title: &str,
) -> Result<Table> {
    let mut g = PlanGraph::new("recon-sweep");
    g.stage_node("pre", None, Stage::Pretrain);
    g.stage_node("dense:eval", Some("pre"), eval_stage(true));
    for &pattern in patterns {
        for &crit in criteria {
            let cell = format!("{}@{}", crit.name(), pattern.label());
            let prune = format!("{cell}:prune");
            g.stage_node(&prune, Some("pre"), prune_stage(crit, pattern));
            // without reconstruction
            g.stage_node(&format!("{cell}:raw"), Some(&prune), eval_stage(true));
            // with MaskLoRA reconstruction.  SparseGPT's own update IS its
            // reconstruction starting point, so targets stay the original
            // dense weights while the walk starts from the pruned state.
            let recon = format!("{cell}:recon");
            g.stage_node(&recon, Some(&prune), Stage::Reconstruct {
                mode: ReconMode::MaskLora,
                steps: None,
                lr: None,
            });
            g.stage_node(&format!("{cell}:recon-eval"), Some(&recon), eval_stage(true));
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let hdr = ["Method", "Reconstruction", "Sparsity", "Perplexity", "Accuracy"];
    let mut t = Table::new(title, &hdr);
    let d = cell_metrics(&report, "dense:eval")?;
    t.row(vec![
        "baseline".into(), "-".into(), "0%".into(), fmt_ppl(d.ppl), fmt_acc(d.acc),
    ]);
    for &pattern in patterns {
        for &crit in criteria {
            let cell = format!("{}@{}", crit.name(), pattern.label());
            let raw = cell_metrics(&report, &format!("{cell}:raw"))?;
            t.row(vec![
                crit.name().into(), "no".into(), pattern.label(),
                fmt_ppl(raw.ppl), fmt_acc(raw.acc),
            ]);
            let rec = cell_metrics(&report, &format!("{cell}:recon-eval"))?;
            t.row(vec![
                crit.name().into(), "yes".into(), pattern.label(),
                fmt_ppl(rec.ppl), fmt_acc(rec.acc),
            ]);
        }
    }
    Ok(t)
}

fn table5(ctx: &ExpContext) -> Result<Vec<Table>> {
    let t = recon_sweep(
        ctx,
        &patterns_for_table2(),
        &[Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt],
        "Table 5/15-18: layer-wise reconstruction",
    )?;
    Ok(vec![t])
}

fn table19(ctx: &ExpContext) -> Result<Vec<Table>> {
    let sparsities = [0.4, 0.5, 0.6, 0.7];
    let recon_modes = [("full_ft", ReconMode::FullFt), ("masklora", ReconMode::MaskLora)];

    let mut g = PlanGraph::new("table19");
    g.stage_node("pre", None, Stage::Pretrain);
    for &sp in &sparsities {
        let prune = format!("prune@{sp}");
        g.stage_node(
            &prune,
            Some("pre"),
            prune_stage(Criterion::Magnitude, Pattern::Unstructured(sp)),
        );
        for (label, mode) in recon_modes {
            let recon = format!("{label}@{sp}:recon");
            g.stage_node(&recon, Some(&prune), Stage::Reconstruct {
                mode,
                steps: None,
                lr: None,
            });
            g.stage_node(&format!("{label}@{sp}:eval"), Some(&recon), eval_stage(true));
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let hdr = ["Method", "40%", "50%", "60%", "70%"];
    let mut t = Table::new(
        "Table 19: MaskLoRA vs Full-FT reconstruction (zero-shot acc)",
        &hdr,
    );
    for (label, _) in recon_modes {
        let mut row = vec![label.to_string()];
        for &sp in &sparsities {
            let m = cell_metrics(&report, &format!("{label}@{sp}:eval"))?;
            row.push(fmt_acc(m.acc));
        }
        t.row(row);
    }
    Ok(vec![t])
}

fn table20(ctx: &ExpContext) -> Result<Vec<Table>> {
    // subset-combination ablation over the modes we lower; the full 32-combo
    // grid needs the --ablation artifact set (combo_* executables), which
    // stays on the session path below — combo subsets are not Stage modes.
    let mm = ctx.rt.model(&ctx.cfg.model)?;
    let mode_combos: Vec<(String, Mode)> = vec![
        ("biases".into(), Mode::Biases),
        ("ln".into(), Mode::Ln),
        ("head".into(), Mode::Head),
        ("embed".into(), Mode::Embed),
        ("biases+ln".into(), Mode::BiasesLn),
        ("masklora(+biases+ln)".into(), Mode::MaskLora),
    ];
    let combo_modes: Vec<String> = mm
        .executables
        .keys()
        .filter_map(|k| k.strip_prefix("train_combo_").map(|s| s.to_string()))
        .collect();
    let sparsities = [0.5, 0.7];

    let mut g = PlanGraph::new("table20");
    g.stage_node("pre", None, Stage::Pretrain);
    for &sp in &sparsities {
        let prune = format!("prune@{sp}");
        g.stage_node(
            &prune,
            Some("pre"),
            prune_stage(Criterion::Magnitude, Pattern::Unstructured(sp)),
        );
        g.stage_node(&format!("none@{sp}:eval"), Some(&prune), eval_stage(false));
        for (label, mode) in &mode_combos {
            retrain_cell(&mut g, &prune, &format!("{label}@{sp}"), *mode, None, None, false);
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let mut tables = Vec::new();
    for &sp in &sparsities {
        let hdr = ["Combination", "% trainable", "Perplexity"];
        let mut t = Table::new(
            &format!("Table 20/21: parameter-group ablation at {:.0}%", sp * 100.0),
            &hdr,
        );
        let none = cell_metrics(&report, &format!("none@{sp}:eval"))?;
        t.row(vec!["none".into(), "0.000%".into(), fmt_ppl(none.ppl)]);
        for (label, _) in &mode_combos {
            let m = cell_metrics(&report, &format!("{label}@{sp}:eval"))?;
            let pct = report
                .node(&format!("{label}@{sp}:retrain"))
                .and_then(|r| r.trainable_pct)
                .unwrap_or(0.0);
            t.row(vec![label.clone(), format!("{pct:.3}%"), fmt_ppl(m.ppl)]);
        }
        // generic combo executables (aot --ablation): session path
        if !combo_modes.is_empty() {
            let (base, _) = ctx.pruned_session(
                ctx.cfg.seeds[0],
                Criterion::Magnitude,
                Pattern::Unstructured(sp),
            )?;
            for combo in &combo_modes {
                let mode_key = format!("combo_{combo}");
                let mut s = ctx.clone_session(&base)?;
                s.retrain_custom(&mode_key, ctx.cfg.retrain_steps, ctx.cfg.lr_grid[0])?;
                let cell = ctx.evaluate(&mut s, false, None)?;
                let pct =
                    100.0 * s.mm.trainable_count(&mode_key) as f64 / s.mm.total_params() as f64;
                t.row(vec![combo.replace('_', "+"), format!("{pct:.3}%"), fmt_ppl(cell.ppl)]);
            }
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Build one Tables 22/23 cell chain (strategy × criterion × sparsity ×
/// seed offset) under the given per-seed pretrain root; returns the eval
/// leaf name.  The three strategies at one (criterion, sparsity, seed)
/// share the same prune node — within a single run, not just via the cache.
fn table22_cell(
    g: &mut PlanGraph,
    root: &str,
    strategy: &str,
    crit: Criterion,
    sp: f64,
    offset: u64,
) -> String {
    let suffix = if offset == 0 { String::new() } else { format!("@s{offset}") };
    let prune = format!("{}@{sp}:prune{suffix}", crit.name());
    if g.get(&prune).is_none() {
        g.stage_node_at(
            &prune,
            Some(root),
            prune_stage(crit, Pattern::Unstructured(sp)),
            offset,
        );
    }
    let cell = format!("{strategy}-{}@{sp}", crit.name());
    let eval = format!("{cell}:eval{suffix}");
    match strategy {
        "none" => {
            g.stage_node_at(&eval, Some(&prune), eval_stage(false), offset);
        }
        "reconstruct" => {
            let recon = format!("{cell}:recon{suffix}");
            g.stage_node_at(&recon, Some(&prune), Stage::Reconstruct {
                mode: ReconMode::MaskLora,
                steps: None,
                lr: None,
            }, offset);
            g.stage_node_at(&eval, Some(&recon), eval_stage(false), offset);
        }
        "retrain" => {
            let retrain = format!("{cell}:retrain{suffix}");
            g.stage_node_at(&retrain, Some(&prune), Stage::Retrain {
                mode: Mode::MaskLora,
                steps: None,
                lr: None,
            }, offset);
            let merge = format!("{cell}:merge{suffix}");
            g.stage_node_at(&merge, Some(&retrain), Stage::Merge, offset);
            g.stage_node_at(&eval, Some(&merge), eval_stage(false), offset);
        }
        other => panic!("unknown table22 strategy {other:?} (none|reconstruct|retrain)"),
    }
    eval
}

fn table22(ctx: &ExpContext) -> Result<Vec<Table>> {
    let criteria = [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt];
    let strategies = ["none", "reconstruct", "retrain"];
    let sparsities = [0.5, 0.6, 0.7, 0.8];
    // mean±std across the profile's seeds: each seed in cfg.seeds becomes a
    // replicated subgraph at offset seeds[i] − seeds[0] over the executor's
    // base seed (= seeds[0]), so the effective seeds are EXACTLY the
    // configured list — [5, 50] runs seeds {5, 50}, not {5, 6}.  An
    // Aggregate node reduces the per-seed eval leaves (quick profile: one
    // seed, plain cells; multi-seed profiles report m±s)
    let seeds = &ctx.cfg.seeds;
    let offsets: Vec<u64> = seeds.iter().map(|s| s.wrapping_sub(seeds[0])).collect();
    let n_seeds = offsets.len();

    let mut g = PlanGraph::new("table22");
    for &offset in &offsets {
        let root = if offset == 0 { "pre".to_string() } else { format!("pre@s{offset}") };
        g.stage_node_at(&root, None, Stage::Pretrain, offset);
        for crit in criteria {
            for strategy in strategies {
                for sp in sparsities {
                    table22_cell(&mut g, &root, strategy, crit, sp, offset);
                }
            }
        }
    }
    if n_seeds > 1 {
        for crit in criteria {
            for strategy in strategies {
                for sp in sparsities {
                    let cell = format!("{strategy}-{}@{sp}", crit.name());
                    let over: Vec<String> = offsets
                        .iter()
                        .map(|&o| {
                            if o == 0 {
                                format!("{cell}:eval")
                            } else {
                                format!("{cell}:eval@s{o}")
                            }
                        })
                        .collect();
                    g.aggregate_node(&format!("{cell}:agg"), over);
                }
            }
        }
    }
    let report = ctx.executor().run_graph(&g)?;

    let hdr = ["Method", "Strategy", "50%", "60%", "70%", "80%"];
    let title = if n_seeds > 1 {
        format!(
            "Tables 22/23: high-sparsity regime — reconstruction vs retraining (ppl, mean±std over {n_seeds} seeds)"
        )
    } else {
        "Tables 22/23: high-sparsity regime — reconstruction vs retraining (ppl)".to_string()
    };
    let mut t = Table::new(&title, &hdr);
    for crit in criteria {
        for strategy in strategies {
            let mut row = vec![crit.name().to_string(), strategy.to_string()];
            for sp in sparsities {
                let cell = format!("{strategy}-{}@{sp}", crit.name());
                if n_seeds > 1 {
                    let agg = report
                        .aggregate(&format!("{cell}:agg"))
                        .with_context(|| format!("no aggregate for cell {cell:?}"))?;
                    row.push(agg.ppl.display(2));
                } else {
                    let m = cell_metrics(&report, &format!("{cell}:eval"))?;
                    row.push(fmt_ppl(m.ppl));
                }
            }
            t.row(row);
        }
    }
    Ok(vec![t])
}

fn memory(_ctx: &ExpContext) -> Result<Vec<Table>> {
    let hdr = ["Method", "GiB (30B model)", "fits one A100-80G"];
    let mut t = Table::new("Memory model: the paper's 30B-on-one-GPU claim", &hdr);
    for (name, gib, fits) in crate::metrics::opt30b_fits_table() {
        t.row(vec![name, format!("{gib:.0}"), if fits { "yes" } else { "NO" }.into()]);
    }
    Ok(vec![t])
}

// re-export for main.rs
pub use crate::util::bench::Table as SweepTable;
