//! Minimal blocking HTTP/1.1 client — just enough to drive the serving
//! endpoints from `repro bench-serve` and the integration tests.  One
//! request per connection, mirroring the server's `Connection: close`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Generous ceiling: a `/generate` against a cold engine may sit behind a
/// pretraining run on first boot.
const READ_TIMEOUT: Duration = Duration::from_secs(600);

pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    let text = String::from_utf8_lossy(&raw);
    let Some((head, rest)) = text.split_once("\r\n\r\n") else {
        bail!("malformed response (no header terminator)");
    };
    let status_line = head.lines().next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {status_line:?}"))?;
    Ok((status, rest.to_string()))
}

pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    request(addr, "GET", path, None)
}

/// POST a JSON value and parse the JSON response body.
pub fn post_json(addr: SocketAddr, path: &str, body: &Json) -> Result<(u16, Json)> {
    let (status, text) = request(addr, "POST", path, Some(&body.to_string()))?;
    let parsed = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("non-json response ({status}): {e} — body {text:?}"))?;
    Ok((status, parsed))
}
