//! SparseGPT (Frantar & Alistarh 2023): layer-wise OBS pruning with error
//! compensation — implemented from scratch on host tensors.
//!
//! Per linear layer with weights W:(out, in) and calibration Hessian
//! H = XᵀX + λI over the input dim:
//!
//! 1. `Hinv` = upper Cholesky factor of H⁻¹ (see `tensor::linalg`);
//! 2. sweep the input columns in blocks of `blocksize`:
//!    * score every weight `w_ij² / Hinv_jj²`;
//!    * unstructured: prune the `sparsity` quantile within the block;
//!      N:M: prune the (m−n) lowest scores of every m-column group;
//!    * for each pruned column, distribute the error
//!      `(w − q)/Hinv_jj` onto the remaining columns via the Hinv row
//!      (the OBS update), first inside the block, then lazily onto all
//!      later columns.
//!
//! The result is both a mask and an *updated* weight matrix — SparseGPT
//! reconstructs as it prunes, which is why the paper's Table 5 shows it
//! ahead of Wanda/magnitude even before any extra reconstruction.

use crate::tensor::{linalg, Tensor};

use super::Pattern;

pub const DEFAULT_BLOCKSIZE: usize = 128;
pub const DEFAULT_PERCDAMP: f64 = 0.01;

pub struct SparseGptResult {
    pub mask: Tensor,
    pub weights: Tensor,
    /// Σ (w−q)²/d² — the cumulative OBS error (diagnostic)
    pub obs_error: f64,
}

/// Run SparseGPT on one layer.  `gram` is the accumulated XᵀX (in, in).
pub fn prune_layer(
    w0: &Tensor,
    gram: &Tensor,
    pattern: Pattern,
    blocksize: usize,
    percdamp: f64,
) -> SparseGptResult {
    let (rows, cols) = (w0.rows(), w0.cols());
    assert_eq!(gram.rows(), cols, "gram dim mismatch");
    let hinv = linalg::sparsegpt_hinv(gram, percdamp);
    let mut w = w0.clone();
    let mut mask = Tensor::ones(&[rows, cols]);
    let mut obs_error = 0.0f64;

    let mut i1 = 0;
    while i1 < cols {
        let i2 = (i1 + blocksize).min(cols);
        let count = i2 - i1;

        // --- choose the block mask -------------------------------------
        // score = w² / Hinv_jj²
        let mut block_mask = vec![1.0f32; rows * count];
        match pattern {
            Pattern::Unstructured(f) => {
                let mut scores = Vec::with_capacity(rows * count);
                for r in 0..rows {
                    for j in 0..count {
                        let d = hinv.at2(i1 + j, i1 + j);
                        let s = w.at2(r, i1 + j);
                        scores.push((s * s) / (d * d));
                    }
                }
                let k = (f * scores.len() as f64).round() as usize;
                let smallest = super::mask_smallest_k_by(&scores, k);
                for (i, &m) in smallest.iter().enumerate() {
                    block_mask[i] = m;
                }
            }
            Pattern::SemiStructured { n, m } => {
                assert!(count % m == 0 || i2 == cols, "block not group aligned");
                for r in 0..rows {
                    let mut g = 0;
                    while g + m <= count {
                        // rank the m-group by score, prune the m-n smallest
                        let mut idx: Vec<usize> = (0..m).collect();
                        let score = |j: usize| {
                            let d = hinv.at2(i1 + g + j, i1 + g + j);
                            let x = w.at2(r, i1 + g + j);
                            (x * x) / (d * d)
                        };
                        idx.sort_by(|&a, &b| {
                            score(a)
                                .partial_cmp(&score(b))
                                .unwrap_or(std::cmp::Ordering::Equal)
                                .then(a.cmp(&b))
                        });
                        for &j in idx.iter().take(m - n) {
                            block_mask[r * count + g + j] = 0.0;
                        }
                        g += m;
                    }
                }
            }
        }

        // --- column sweep with OBS updates ------------------------------
        // Err1[r][j] accumulates the per-column errors for the lazy tail
        // update after the block completes.
        let mut err1 = vec![0.0f32; rows * count];
        for j in 0..count {
            let col = i1 + j;
            let d = hinv.at2(col, col);
            for r in 0..rows {
                let keep = block_mask[r * count + j];
                let wv = w.at2(r, col);
                let q = if keep == 1.0 { wv } else { 0.0 };
                let e = (wv - q) / d;
                obs_error += (e as f64) * (e as f64);
                if keep == 0.0 {
                    mask.set2(r, col, 0.0);
                    w.set2(r, col, 0.0);
                }
                if e != 0.0 {
                    // propagate within the block: W[r, col+1..i2] -= e * Hinv[col, ...]
                    for t in (j + 1)..count {
                        let upd = e * hinv.at2(col, i1 + t);
                        let cur = w.at2(r, i1 + t);
                        w.set2(r, i1 + t, cur - upd);
                    }
                }
                err1[r * count + j] = e;
            }
        }

        // --- lazy update of all later columns ---------------------------
        if i2 < cols {
            for r in 0..rows {
                for j in 0..count {
                    let e = err1[r * count + j];
                    if e == 0.0 {
                        continue;
                    }
                    let col = i1 + j;
                    for t in i2..cols {
                        let upd = e * hinv.at2(col, t);
                        let cur = w.at2(r, t);
                        w.set2(r, t, cur - upd);
                    }
                }
            }
        }
        i1 = i2;
    }

    // pruned entries end exactly zero (they may have received tail updates
    // *before* their column was processed, never after)
    debug_assert!({
        let mut ok = true;
        for r in 0..rows {
            for c in 0..cols {
                if mask.at2(r, c) == 0.0 && w.at2(r, c) != 0.0 {
                    ok = false;
                }
            }
        }
        ok
    });

    SparseGptResult { mask, weights: w, obs_error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{magnitude, semistructured};
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    /// Calibration inputs with correlated features — the regime where OBS
    /// error compensation matters.
    fn calib_x(n: usize, d: usize, rng: &mut Rng) -> Tensor {
        let base = Tensor::randn(&[n, d], 1.0, rng);
        let mut x = base.clone();
        // mix neighbours to induce correlations
        for r in 0..n {
            for c in 1..d {
                let v = 0.7 * x.at2(r, c - 1) + 0.5 * base.at2(r, c);
                x.set2(r, c, v);
            }
        }
        x
    }

    fn recon_error(w0: &Tensor, w: &Tensor, x: &Tensor) -> f64 {
        let y0 = linalg::matmul_nt(x, w0);
        let y1 = linalg::matmul_nt(x, w);
        y0.sub(&y1).sq_norm()
    }

    #[test]
    fn achieves_target_sparsity_and_zeroes() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let x = calib_x(128, 64, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let res = prune_layer(&w, &gram, Pattern::Unstructured(0.5), 16, 0.01);
        let s = res.mask.zero_fraction();
        assert!((s - 0.5).abs() < 0.02, "{s}");
        for (m, v) in res.mask.data().iter().zip(res.weights.data()) {
            if *m == 0.0 {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn beats_plain_magnitude_on_reconstruction() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[24, 96], 1.0, &mut rng);
        let x = calib_x(256, 96, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let res = prune_layer(&w, &gram, Pattern::Unstructured(0.6), 32, 0.01);

        let mut wm = BTreeMap::new();
        wm.insert("w".to_string(), &w);
        let mag = magnitude::uniform(&wm, Pattern::Unstructured(0.6));
        let w_mag = w.hadamard(mag.get("w"));

        let e_sgpt = recon_error(&w, &res.weights, &x);
        let e_mag = recon_error(&w, &w_mag, &x);
        assert!(
            e_sgpt < 0.8 * e_mag,
            "sparsegpt {e_sgpt:.1} should beat magnitude {e_mag:.1}"
        );
    }

    #[test]
    fn update_matters_vs_mask_only() {
        // masking with the SparseGPT mask but WITHOUT the OBS updates must be
        // worse — proves the compensation is doing real work.
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[16, 64], 1.0, &mut rng);
        let x = calib_x(192, 64, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let res = prune_layer(&w, &gram, Pattern::Unstructured(0.5), 16, 0.01);
        let mask_only = w.hadamard(&res.mask);
        let e_full = recon_error(&w, &res.weights, &x);
        let e_mask = recon_error(&w, &mask_only, &x);
        assert!(e_full < e_mask, "updates should reduce error: {e_full} vs {e_mask}");
    }

    #[test]
    fn nm_pattern_respected() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[8, 64], 1.0, &mut rng);
        let x = calib_x(128, 64, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        for (n, m) in [(2usize, 4usize), (4, 8)] {
            let res = prune_layer(&w, &gram, Pattern::SemiStructured { n, m }, 32, 0.01);
            assert!(
                semistructured::check_nm(&res.mask, n, m),
                "{n}:{m} violated"
            );
        }
    }

    #[test]
    fn identity_hessian_reduces_to_magnitude_blockwise() {
        // With H = I there are no correlations; scores reduce to w² and no
        // compensation flows across columns.
        let mut rng = Rng::new(5);
        let w = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let gram = Tensor::eye(32).scale(100.0); // strong identity, damping negligible
        let res = prune_layer(&w, &gram, Pattern::Unstructured(0.5), 32, 1e-6);
        // kept weights unchanged
        for r in 0..4 {
            for c in 0..32 {
                if res.mask.at2(r, c) == 1.0 {
                    assert!((res.weights.at2(r, c) - w.at2(r, c)).abs() < 1e-4);
                }
            }
        }
        assert!((res.mask.zero_fraction() - 0.5).abs() < 0.02);
    }

    #[test]
    fn zero_sparsity_is_identity() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let x = calib_x(64, 32, &mut rng);
        let gram = linalg::matmul(&x.transpose2(), &x);
        let res = prune_layer(&w, &gram, Pattern::Unstructured(0.0), 16, 0.01);
        assert_eq!(res.mask.zero_fraction(), 0.0);
        assert!(res.weights.allclose(&w, 1e-6, 1e-6));
        assert_eq!(res.obs_error, 0.0);
    }
}
