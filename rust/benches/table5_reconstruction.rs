//! `cargo bench --bench table5_reconstruction` — regenerates the paper's table5
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("table5");
}
