//! Execution backends: compile/execute named graphs over named tensor I/O.
//!
//! The [`Backend`] trait is the seam between the coordinator (which owns all
//! state host-side and thinks in manifest names) and whatever actually
//! computes:
//!
//! * [`NativeBackend`] — the default.  A pure-rust, rayon-parallel
//!   implementation of every lowered graph (forward, loss, backward, AdamW,
//!   layer-wise reconstruction) driven by the builtin manifest.  Hermetic:
//!   zero native dependencies, no artifacts directory.
//! * `PjrtBackend` (cargo feature `pjrt`) — the original AOT path: HLO-text
//!   artifacts produced by `python/compile/aot.py`, compiled once per
//!   (model, executable) on the PJRT CPU client.
//!
//! Both speak [`Feed`] (named inputs, resolved by manifest `IoSpec`s) and
//! [`Outputs`] (named host tensors), so the coordinator/eval/bench layers are
//! backend-blind.  Select at runtime with `--backend {native,pjrt}` or the
//! `PERP_BACKEND` environment variable.

pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

pub use manifest::{
    split_adapter_name, DType, ExecSpec, IoSpec, Manifest, ModelCfg, ModelManifest,
};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::tensor::sparse::{SparseForm, SparseStore, WeightLayout};
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Feed: named tensors for one execution.
// ---------------------------------------------------------------------------

/// Input values for one execution, resolved by manifest input name.
///
/// The coordinator layers register providers by prefix (`p::`, `m::`, ...)
/// through [`Feed::provider`]; one-off tensors (tokens, scalars) go in via
/// [`Feed::tensor`] / [`Feed::ints`] / [`Feed::scalar`].
///
/// Compressed weight forms travel on a dedicated side channel
/// ([`Feed::form`] / [`Feed::weight_layout`], usually attached wholesale via
/// [`Feed::sparse`]): they are execution *hints* outside the manifest's
/// `ExecSpec` contract — backends that cannot exploit them (PJRT) simply
/// ignore them, and the dense params/masks are always fed alongside.
#[derive(Default)]
pub struct Feed<'a> {
    tensors: HashMap<String, &'a Tensor>,
    owned: HashMap<String, Tensor>,
    ints: HashMap<String, (&'a [usize], &'a [i32])>,
    providers: Vec<&'a dyn Fn(&str) -> Option<&'a Tensor>>,
    forms: HashMap<String, &'a SparseForm>,
    layouts: HashMap<String, WeightLayout>,
}

impl<'a> Feed<'a> {
    pub fn new() -> Feed<'a> {
        Feed::default()
    }
    pub fn tensor(mut self, name: &str, t: &'a Tensor) -> Self {
        self.tensors.insert(name.to_string(), t);
        self
    }
    /// Borrow with an owned key (hot loops that format names per step).
    pub fn owned_key(mut self, name: String, t: &'a Tensor) -> Self {
        self.tensors.insert(name, t);
        self
    }
    pub fn owned(mut self, name: &str, t: Tensor) -> Self {
        self.owned.insert(name.to_string(), t);
        self
    }
    pub fn scalar(self, name: &str, v: f32) -> Self {
        self.owned(name, Tensor::scalar(v))
    }
    pub fn ints(mut self, name: &str, shape: &'a [usize], data: &'a [i32]) -> Self {
        self.ints.insert(name.to_string(), (shape, data));
        self
    }
    /// Register a fallback resolver (e.g. ParamStore lookup for `p::*`).
    pub fn provider(mut self, f: &'a dyn Fn(&str) -> Option<&'a Tensor>) -> Self {
        self.providers.push(f);
        self
    }

    /// Resolve an f32 input by name: direct tensors, then owned, then
    /// providers.
    pub fn get_tensor(&self, name: &str) -> Option<&Tensor> {
        if let Some(t) = self.tensors.get(name) {
            return Some(*t);
        }
        if let Some(t) = self.owned.get(name) {
            return Some(t);
        }
        self.providers.iter().find_map(|p| p(name))
    }

    /// Resolve an i32 input by name.
    pub fn get_ints(&self, name: &str) -> Option<(&[usize], &[i32])> {
        self.ints.get(name).map(|(s, d)| (*s, *d))
    }

    /// Attach one weight's compressed form (keyed by the weight name).
    pub fn form(mut self, name: &str, m: &'a SparseForm) -> Self {
        self.forms.insert(name.to_string(), m);
        self
    }

    /// Pin one weight's resolved execution layout.
    pub fn weight_layout(mut self, name: &str, l: WeightLayout) -> Self {
        self.layouts.insert(name.to_string(), l);
        self
    }

    /// Attach a whole [`SparseStore`]: every resolved layout plus every
    /// cached compressed form — the one-liner the coordinator hot loops use.
    pub fn sparse(mut self, store: &'a SparseStore) -> Self {
        for (n, f) in &store.forms {
            self.forms.insert(n.clone(), f);
        }
        self.weight_layouts(store)
    }

    /// Attach only the resolved layouts, not the compressed forms — for
    /// loops whose cached weight *values* would be stale (full-FT training)
    /// or whose routed layout is approximate (quantised policies during
    /// training).  Dense/Masked routing needs no values, so it stays
    /// honoured; a compressed-routed layer without its form falls back to
    /// the exact Masked kernels.
    pub fn weight_layouts(mut self, store: &SparseStore) -> Self {
        for (n, l) in &store.layouts {
            self.layouts.insert(n.clone(), *l);
        }
        self
    }

    pub fn get_form(&self, name: &str) -> Option<&'a SparseForm> {
        self.forms.get(name).copied()
    }

    pub fn get_weight_layout(&self, name: &str) -> Option<WeightLayout> {
        self.layouts.get(name).copied()
    }
}

// ---------------------------------------------------------------------------
// Outputs: named tensors from one execution.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Outputs {
    pub values: Vec<(String, Tensor)>,
}

impl Outputs {
    pub fn get(&self, name: &str) -> &Tensor {
        &self
            .values
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output {name:?}"))
            .1
    }
    pub fn take(&mut self, name: &str) -> Tensor {
        let idx = self
            .values
            .iter()
            .position(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output {name:?}"));
        self.values.swap_remove(idx).1
    }
    pub fn scalar(&self, name: &str) -> f32 {
        self.get(name).data()[0]
    }
    /// Drain outputs whose name starts with `prefix`, stripping it.
    pub fn drain_prefix(&mut self, prefix: &str) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        let mut rest = Vec::new();
        for (n, t) in self.values.drain(..) {
            if let Some(stripped) = n.strip_prefix(prefix) {
                out.push((stripped.to_string(), t));
            } else {
                rest.push((n, t));
            }
        }
        self.values = rest;
        out
    }
}

// ---------------------------------------------------------------------------
// The Backend trait.
// ---------------------------------------------------------------------------

/// An execution engine for the manifest's named graphs.
///
/// Implementations cache per-(model, executable) compiled state — reported by
/// [`Backend::compiled_count`] — and count executions for the metrics layer.
/// Object-safe on purpose: the coordinator holds `&dyn Backend`.  `Send +
/// Sync` because the plan-graph scheduler executes independent subtrees on
/// worker threads sharing one backend reference — implementations keep
/// their execution counters and compile caches behind atomics/locks.
pub trait Backend: Send + Sync {
    /// Short identifier ("native" / "pjrt") for logs and tables.
    fn kind(&self) -> &'static str;

    /// The model inventory this backend executes against.
    fn manifest(&self) -> &Manifest;

    fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest().model(name)
    }

    /// Warm the per-(model, executable) cache (PJRT: compile the HLO) without
    /// executing.  Idempotent.
    fn prepare(&self, model: &str, exec: &str) -> Result<()>;

    /// Execute one named graph over a [`Feed`]; returns named host tensors in
    /// manifest output order.
    fn run(&self, model: &str, exec: &str, feed: &Feed) -> Result<Outputs>;

    /// Executions performed so far (metrics).
    fn exec_count(&self) -> u64;

    /// Distinct (model, executable) pairs prepared/compiled so far.
    fn compiled_count(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Backend selection.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend {other:?} (native|pjrt)")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Open a backend by kind.  `artifacts` is only consulted by the PJRT path.
pub fn open_backend(kind: BackendKind, artifacts: &Path) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            let _ = artifacts;
            Ok(Box::new(NativeBackend::new()))
        }
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Box::new(PjrtBackend::new(artifacts)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts;
                anyhow::bail!(
                    "this build has no PJRT support; rebuild with `--features pjrt` \
                     or use --backend native"
                )
            }
        }
    }
}

/// Backend for examples/benches: `$PERP_BACKEND` (native|pjrt), default
/// native; the PJRT path reads artifacts from [`default_artifacts_dir`].
pub fn open_default_backend() -> Result<Box<dyn Backend>> {
    let kind = match std::env::var("PERP_BACKEND") {
        Ok(v) => BackendKind::parse(&v).map_err(|e| anyhow::anyhow!(e))?,
        Err(_) => BackendKind::Native,
    };
    open_backend(kind, &default_artifacts_dir())
}

/// Default artifacts directory: `$PERP_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("PERP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Native.name(), "native");
    }

    #[test]
    fn open_native_backend_works() {
        let b = open_backend(BackendKind::Native, Path::new("/nonexistent")).unwrap();
        assert_eq!(b.kind(), "native");
        assert!(b.model("gpt-nano").is_ok());
        assert_eq!(b.exec_count(), 0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_clear_error() {
        let err = open_backend(BackendKind::Pjrt, Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }

    #[test]
    fn feed_lookup_precedence_and_providers() {
        let a = Tensor::scalar(1.0);
        let provided = Tensor::scalar(3.0);
        let lookup = |name: &str| if name == "p::x" { Some(&provided) } else { None };
        let feed = Feed::new()
            .tensor("a", &a)
            .owned("b", Tensor::scalar(2.0))
            .provider(&lookup);
        assert_eq!(feed.get_tensor("a").unwrap().data()[0], 1.0);
        assert_eq!(feed.get_tensor("b").unwrap().data()[0], 2.0);
        assert_eq!(feed.get_tensor("p::x").unwrap().data()[0], 3.0);
        assert!(feed.get_tensor("missing").is_none());
        let shape = [2usize];
        let data = [5i32, 6];
        let feed = Feed::new().ints("tok", &shape, &data);
        let (s, d) = feed.get_ints("tok").unwrap();
        assert_eq!(s, &[2]);
        assert_eq!(d, &[5, 6]);
        assert!(feed.get_ints("nope").is_none());
    }
}
