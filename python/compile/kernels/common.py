"""Shared helpers for the Pallas kernels (L1).

All kernels in this package run with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode lowers the kernels to
plain HLO ops which any backend (including the rust-side PJRT CPU client)
executes natively.  Block shapes are still chosen as if targeting a TPU core
(VMEM ~16 MiB, MXU-friendly multiples of 8/128) so the HBM<->VMEM schedule the
BlockSpecs express is the one we analyze in DESIGN.md §Perf.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def scratch(shape, dtype=jnp.float32):
    """A VMEM-style scratch accumulator (ANY memory space interprets on CPU)."""
    return pl.MemorySpace.ANY(shape, dtype)


# Kernels must be interpretable on CPU; flip to False only when compiling for
# a real TPU target (compile-only validation).
INTERPRET = True


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the next multiple of ``b``."""
    return cdiv(a, b) * b


def pick_block(dim: int, preferred: int) -> int:
    """Choose a block size for ``dim``: the preferred tile if the dimension is
    large enough and divisible, otherwise the whole (small) dimension.

    The tiny/small model configs used for CPU reproduction have dims (64-1024)
    that often fit in a single tile; the preferred sizes (128/256) are the
    MXU-friendly tiles we would use on real hardware.
    """
    if dim % preferred == 0:
        return preferred
    # fall back to the largest power-of-two divisor <= preferred
    b = 1
    while b * 2 <= preferred and dim % (b * 2) == 0:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class MatmulBlocks:
    """Tile sizes for an (n, k) x (m, k)^T -> (n, m) contraction."""

    bn: int
    bm: int
    bk: int

    @staticmethod
    def choose(n: int, m: int, k: int) -> "MatmulBlocks":
        return MatmulBlocks(
            bn=pick_block(n, 128),
            bm=pick_block(m, 128),
            bk=pick_block(k, 256),
        )

    def grid(self, n: int, m: int, k: int):
        return (cdiv(n, self.bn), cdiv(m, self.bm), cdiv(k, self.bk))

    def vmem_bytes(self, rank: int = 0, dtype_bytes: int = 4) -> int:
        """Analytical VMEM footprint of one grid step (perf model input).

        x-tile + w-tile + mask-tile + (optional lora tiles) + acc + out.
        """
        tiles = (
            self.bn * self.bk  # x
            + self.bm * self.bk  # w
            + self.bm * self.bk  # mask
            + self.bn * self.bm * 2  # acc + out
        )
        if rank:
            tiles += self.bm * rank + rank * self.bk + self.bm * self.bk
        return tiles * dtype_bytes


def flops_masked_lora(n: int, m: int, k: int, r: int) -> int:
    """FLOP count for the fused (W*M + s*M*(B@A)) @ x^T contraction."""
    main = 2 * n * m * k  # the MXU contraction
    lora = 2 * m * r * k  # B@A materialisation per (m,k) tile sweep
    mask = 3 * m * k  # two hadamards + add
    return main + lora + mask


def assert_rank(x: jax.Array, rank: int, name: str) -> None:
    if x.ndim != rank:
        raise ValueError(f"{name}: expected rank {rank}, got shape {x.shape}")


def pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` to a multiple of ``multiple``."""
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def maybe_jit(fn):
    """jit wrapper that keeps the python call path usable under pytest."""
    return functools.wraps(fn)(jax.jit(fn))
