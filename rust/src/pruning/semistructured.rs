//! N:M semi-structured masks (Mishra et al. 2021): within every group of M
//! consecutive *input* weights, keep the N largest by |w| (or by an external
//! score).  Tie-break: ascending in-group index — byte-identical to the L1
//! `nm_mask` kernel and `ref.semistructured_mask`.

use crate::tensor::Tensor;

/// N:M magnitude mask for w:(out, in).
pub fn nm_mask(w: &Tensor, n: usize, m: usize) -> Tensor {
    nm_mask_scored(w, &w.abs(), n, m)
}

/// N:M mask keeping the N highest-*score* entries per group (Wanda/SparseGPT
/// reuse this with their own score tensors).
pub fn nm_mask_scored(w: &Tensor, scores: &Tensor, n: usize, m: usize) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(w.shape(), scores.shape());
    assert!(
        cols % m == 0,
        "input dim {cols} not divisible by group size {m}"
    );
    assert!(n <= m, "cannot keep {n} of {m}");
    let mut mask = Tensor::zeros(&[rows, cols]);
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    for r in 0..rows {
        let srow = scores.row(r);
        for g in 0..cols / m {
            let base = g * m;
            idx.clear();
            idx.extend(0..m);
            idx.sort_by(|&a, &b| {
                srow[base + b]
                    .partial_cmp(&srow[base + a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            for &i in idx.iter().take(n) {
                mask.set2(r, base + i, 1.0);
            }
        }
    }
    mask
}

/// Validate the N:M invariant on a mask.
pub fn check_nm(mask: &Tensor, n: usize, m: usize) -> bool {
    let (rows, cols) = (mask.rows(), mask.cols());
    if cols % m != 0 {
        return false;
    }
    for r in 0..rows {
        let row = mask.row(r);
        for g in 0..cols / m {
            let kept: usize = row[g * m..(g + 1) * m]
                .iter()
                .filter(|&&x| x == 1.0)
                .count();
            if kept != n {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn two_four_keeps_top2() {
        let w = Tensor::new(&[1, 4], vec![0.1, -3.0, 2.0, 0.5]);
        let m = nm_mask(&w, 2, 4);
        assert_eq!(m.data(), &[0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn ties_break_by_index() {
        let w = Tensor::new(&[1, 4], vec![1.0, 1.0, 1.0, 1.0]);
        let m = nm_mask(&w, 2, 4);
        assert_eq!(m.data(), &[1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn rejects_bad_group() {
        let w = Tensor::zeros(&[2, 6]);
        assert!(std::panic::catch_unwind(|| nm_mask(&w, 2, 4)).is_err());
    }

    #[test]
    fn prop_nm_invariant_holds() {
        prop::check("nm_invariant", 30, |g| {
            let rows = g.dim(16).max(1);
            let (n, m) = *g.rng.choice(&[(1usize, 4usize), (2, 4), (4, 8), (2, 8)]);
            let groups = g.dim_multiple_of(1, 8);
            let cols = groups * m;
            let w = Tensor::new(&[rows, cols], g.tensor(rows * cols, 1.0));
            let mask = nm_mask(&w, n, m);
            assert!(check_nm(&mask, n, m));
            // kept entries have scores >= dropped within each group
            for r in 0..rows {
                for gi in 0..cols / m {
                    let base = gi * m;
                    let min_kept = (0..m)
                        .filter(|&i| mask.at2(r, base + i) == 1.0)
                        .map(|i| w.at2(r, base + i).abs())
                        .fold(f32::INFINITY, f32::min);
                    let max_dropped = (0..m)
                        .filter(|&i| mask.at2(r, base + i) == 0.0)
                        .map(|i| w.at2(r, base + i).abs())
                        .fold(0.0f32, f32::max);
                    assert!(min_kept >= max_dropped - 1e-6);
                }
            }
        });
    }

    #[test]
    fn scored_variant_uses_scores_not_weights() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[2, 8], 1.0, &mut rng);
        // scores force keeping the *first* n of each group
        let mut s = Tensor::zeros(&[2, 8]);
        for r in 0..2 {
            for c in 0..8 {
                s.set2(r, c, if c % 4 < 2 { 10.0 } else { 0.0 });
            }
        }
        let mask = nm_mask_scored(&w, &s, 2, 4);
        for r in 0..2 {
            for c in 0..8 {
                assert_eq!(mask.at2(r, c), if c % 4 < 2 { 1.0 } else { 0.0 });
            }
        }
    }
}
