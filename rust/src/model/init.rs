//! Parameter initialisation (GPT-2/OPT convention), host-side.
//!
//! Weights ~ N(0, 0.02), residual-output projections scaled by 1/sqrt(2L)
//! (the GPT-2 depth correction), biases zero, norm scales one.  Doing this in
//! rust keeps python strictly on the compile path — no init executable.

use crate::runtime::ModelManifest;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

const INIT_STD: f32 = 0.02;

pub fn init_params(mm: &ModelManifest, rng: &mut Rng) -> super::ParamStore {
    let mut store = super::ParamStore::zeros(mm);
    let depth_scale = 1.0 / ((2 * mm.cfg.n_layers) as f32).sqrt();
    for p in &mm.params {
        let t = if p.name.ends_with("_scale") {
            Tensor::ones(&p.shape)
        } else if p.name.ends_with("_b") || p.name.ends_with("_bias") {
            Tensor::zeros(&p.shape)
        } else {
            // residual-stream output projections get the depth correction
            let std = if p.name.contains("attn_o") || p.name.contains("mlp_proj") {
                INIT_STD * depth_scale
            } else {
                INIT_STD
            };
            Tensor::randn(&p.shape, std, &mut rng.fork(hash_name(&p.name)))
        };
        store.set(&p.name, t);
    }
    store
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a — per-tensor streams stay stable however iteration order changes
    let mut h = 0xcbf29ce484222325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn init_statistics() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let mut rng = Rng::new(0);
        let ps = init_params(mm, &mut rng);
        // scales are 1, biases 0
        assert!(ps.get("h0_ln1_scale").data().iter().all(|&x| x == 1.0));
        assert!(ps.get("h0_attn_q_b").data().iter().all(|&x| x == 0.0));
        // weights roughly N(0, 0.02)
        let w = ps.get("h0_attn_q_w");
        let std = (w.sq_norm() / w.numel() as f64).sqrt();
        assert!((std - 0.02).abs() < 0.005, "{std}");
        // depth-corrected projection is smaller
        let o = ps.get("h0_attn_o_w");
        let ostd = (o.sq_norm() / o.numel() as f64).sqrt();
        assert!(ostd < std, "{ostd} vs {std}");
    }

    #[test]
    fn deterministic_per_seed() {
        let m = Manifest::builtin();
        let mm = m.model("gpt-nano").unwrap();
        let a = init_params(mm, &mut Rng::new(5));
        let b = init_params(mm, &mut Rng::new(5));
        let c = init_params(mm, &mut Rng::new(6));
        assert_eq!(a.get("head_w"), b.get("head_w"));
        assert_ne!(a.get("head_w"), c.get("head_w"));
    }
}
