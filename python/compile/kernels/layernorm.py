"""LayerNorm / RMSNorm Pallas kernels.

PERP's cheapest retraining subset is exactly these affine parameters (0.01% of
an OPT model), so the normalisation layers must expose clean grads for scale
and bias.  Forward is a row-blocked pallas kernel (full feature dim per tile —
d ≤ 1024 at repro scale); backward is the closed-form LN VJP in jnp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pick_block, cdiv

EPS = 1e-5


def _ln_kernel(x_ref, s_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * s_ref[...] + b_ref[...]


def layernorm_fwd_kernel(x, scale, bias):
    """x: (n, d); scale/bias: (d,)."""
    n, d = x.shape
    bn = pick_block(n, 256)
    return pl.pallas_call(
        _ln_kernel,
        grid=(cdiv(n, bn),),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=INTERPRET,
    )(x, scale[None, :], bias[None, :])


@jax.custom_vjp
def layernorm(x, scale, bias):
    """y = (x - mu)/sqrt(var + eps) * scale + bias, rows normalised."""
    return layernorm_fwd_kernel(x, scale, bias)


def _ln_fwd(x, scale, bias):
    return layernorm_fwd_kernel(x, scale, bias), (x, scale)


def _ln_bwd(res, g):
    x, scale = res
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * inv
    dbias = jnp.sum(g, axis=0)
    dscale = jnp.sum(g * xhat, axis=0)
    dxhat = g * scale
    # dx = inv * (dxhat - mean(dxhat) - xhat * mean(dxhat * xhat))
    dx = inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )
    return dx, dscale, dbias


layernorm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# RMSNorm (LLaMA-family configs — no bias, no mean subtraction).
# ---------------------------------------------------------------------------


def _rms_kernel(x_ref, s_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + EPS) * s_ref[...]


def rmsnorm_fwd_kernel(x, scale):
    n, d = x.shape
    bn = pick_block(n, 256)
    return pl.pallas_call(
        _rms_kernel,
        grid=(cdiv(n, bn),),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=INTERPRET,
    )(x, scale[None, :])


@jax.custom_vjp
def rmsnorm(x, scale):
    """y = x / sqrt(mean(x^2) + eps) * scale."""
    return rmsnorm_fwd_kernel(x, scale)


def _rms_fwd(x, scale):
    return rmsnorm_fwd_kernel(x, scale), (x, scale)


def _rms_bwd(res, g):
    x, scale = res
    d = x.shape[-1]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + EPS)
    xhat = x * inv
    dscale = jnp.sum(g * xhat, axis=0)
    gs = g * scale
    # dx = inv * (gs - xhat * mean(gs * xhat))
    dx = inv * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    return dx, dscale


rmsnorm.defvjp(_rms_fwd, _rms_bwd)
