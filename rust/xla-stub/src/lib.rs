//! Stub of the `xla` (PJRT bridge) crate.
//!
//! Mirrors exactly the API surface `perp::runtime::pjrt` consumes so that
//! `cargo check --features pjrt` compiles in environments without the XLA
//! native library.  Every constructor fails at *runtime* with a clear
//! message; deployments with the real crate vendored repoint the `xla` path
//! dependency in `rust/Cargo.toml` and nothing else changes.

use std::path::Path;

/// Error type; the real crate's errors are only ever `{:?}`-formatted by the
/// consumer, so a message-carrying struct is a faithful stand-in.
pub struct Error(pub String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT stub: no native XLA library in this build; use --backend native \
         or link the real `xla` crate (see rust/README.md)"
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_guidance() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("--backend native"));
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
