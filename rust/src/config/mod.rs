//! Experiment configuration: defaults, JSON overrides and validation.
//!
//! Every sweep/bench resolves an [`ExperimentConfig`]; the `--profile` axis
//! trades fidelity for wall-clock (CI smoke vs full reproduction).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// model manifest key (gpt-nano .. gpt-medium, llama-tiny)
    pub model: String,
    /// execution backend ("native" | "pjrt"); overridable with --backend
    pub backend: String,
    /// sparse weight layout policy ("auto" | "auto-q" | "dense" | "masked" |
    /// "csr" | "bsr" | "csr-f16" | "csr-q8" | "bsr-f16" | "bsr-q8");
    /// overridable with --layout.  Auto picks a bitwise-exact layout per
    /// layer from the measured crossover table (PERP_CROSSOVER_TABLE, or the
    /// PERP_CSR_CROSSOVER single-threshold fallback, default 0.75); auto-q
    /// and the explicit *-f16/*-q8 layouts are approximate and eval/decode
    /// only.
    pub layout: String,
    /// pretraining steps to converge the dense model
    pub pretrain_steps: u64,
    pub pretrain_lr: f64,
    /// retraining iterations after pruning (paper: 1000)
    pub retrain_steps: u64,
    /// tuned peak LRs tried per method (paper: {5e-6 .. 5e-4})
    pub lr_grid: Vec<f64>,
    /// calibration sequences (paper: 128)
    pub calib_seqs: usize,
    /// reconstruction iterations per layer block
    pub recon_steps: u64,
    pub recon_lr: f64,
    /// zero-shot items per task
    pub items_per_task: usize,
    /// eval batches cap for perplexity
    pub eval_batches: usize,
    pub seeds: Vec<u64>,
    pub data_seed: u64,
}

impl ExperimentConfig {
    /// Full-fidelity defaults (paper-shaped).
    pub fn full(model: &str) -> ExperimentConfig {
        ExperimentConfig {
            model: model.to_string(),
            backend: "native".to_string(),
            layout: "auto".to_string(),
            // gpt-nano converges around here; the pruning-collapse shape
            // (Fig 1) only appears on converged models
            pretrain_steps: 30_000,
            pretrain_lr: 1e-3,
            retrain_steps: 200,
            lr_grid: vec![1e-3],
            calib_seqs: 128,
            recon_steps: 60,
            recon_lr: 2e-3,
            items_per_task: 30,
            eval_batches: 8,
            seeds: vec![0, 1],
            data_seed: 1234,
        }
    }

    /// CI smoke profile: every code path, minutes not hours.
    pub fn quick(model: &str) -> ExperimentConfig {
        ExperimentConfig {
            pretrain_steps: 150,
            pretrain_lr: 2e-3,
            retrain_steps: 30,
            lr_grid: vec![1e-3],
            calib_seqs: 16,
            recon_steps: 10,
            recon_lr: 2e-3,
            items_per_task: 10,
            eval_batches: 2,
            seeds: vec![0],
            ..ExperimentConfig::full(model)
        }
    }

    pub fn profile(name: &str, model: &str) -> Result<ExperimentConfig> {
        match name {
            "full" => Ok(ExperimentConfig::full(model)),
            "quick" => Ok(ExperimentConfig::quick(model)),
            other => bail!("unknown profile {other:?} (full|quick)"),
        }
    }

    /// Apply overrides from a JSON file (fields optional).
    pub fn with_file(self, path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).context("parsing config")?;
        self.with_json(&j)
    }

    /// Apply overrides from a parsed JSON object (fields optional).  The
    /// inverse of [`ExperimentConfig::to_json`]: a resolved config persisted
    /// by the job store round-trips to an identical config — and therefore
    /// identical cache keys — because Rust's f64 `Display` emits the
    /// shortest round-trip representation.
    pub fn with_json(mut self, j: &Json) -> Result<ExperimentConfig> {
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            self.model = v.to_string();
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            self.backend = v.to_string();
        }
        if let Some(v) = j.get("layout").and_then(Json::as_str) {
            self.layout = v.to_string();
        }
        if let Some(v) = j.get("pretrain_steps").and_then(Json::as_i64) {
            self.pretrain_steps = v as u64;
        }
        if let Some(v) = j.get("pretrain_lr").and_then(Json::as_f64) {
            self.pretrain_lr = v;
        }
        if let Some(v) = j.get("retrain_steps").and_then(Json::as_i64) {
            self.retrain_steps = v as u64;
        }
        if let Some(v) = j.get("lr_grid").and_then(Json::as_arr) {
            self.lr_grid = v.iter().filter_map(Json::as_f64).collect();
        }
        if let Some(v) = j.get("calib_seqs").and_then(Json::as_usize) {
            self.calib_seqs = v;
        }
        if let Some(v) = j.get("recon_steps").and_then(Json::as_i64) {
            self.recon_steps = v as u64;
        }
        if let Some(v) = j.get("recon_lr").and_then(Json::as_f64) {
            self.recon_lr = v;
        }
        if let Some(v) = j.get("items_per_task").and_then(Json::as_usize) {
            self.items_per_task = v;
        }
        if let Some(v) = j.get("eval_batches").and_then(Json::as_usize) {
            self.eval_batches = v;
        }
        if let Some(v) = j.get("seeds").and_then(Json::as_arr) {
            self.seeds = v.iter().filter_map(Json::as_i64).map(|x| x as u64).collect();
        }
        if let Some(v) = j.get("data_seed").and_then(Json::as_i64) {
            self.data_seed = v as u64;
        }
        self.validate()?;
        Ok(self)
    }

    /// Serialize every field (the exact basis of `base_key` plus the seeds
    /// list and layout) so a job record can persist its *resolved* config:
    /// `ExperimentConfig::quick(m).with_json(&c.to_json()) == c`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("layout", Json::Str(self.layout.clone())),
            ("pretrain_steps", Json::Num(self.pretrain_steps as f64)),
            ("pretrain_lr", Json::Num(self.pretrain_lr)),
            ("retrain_steps", Json::Num(self.retrain_steps as f64)),
            ("lr_grid", Json::Arr(self.lr_grid.iter().map(|&v| Json::Num(v)).collect())),
            ("calib_seqs", Json::Num(self.calib_seqs as f64)),
            ("recon_steps", Json::Num(self.recon_steps as f64)),
            ("recon_lr", Json::Num(self.recon_lr)),
            ("items_per_task", Json::Num(self.items_per_task as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("seeds", Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect())),
            ("data_seed", Json::Num(self.data_seed as f64)),
        ])
    }

    pub fn validate(&self) -> Result<()> {
        crate::runtime::BackendKind::parse(&self.backend).map_err(|e| anyhow::anyhow!(e))?;
        crate::tensor::sparse::LayoutPolicy::parse(&self.layout)
            .map_err(|e| anyhow::anyhow!(e))?;
        if self.lr_grid.is_empty() {
            bail!("lr_grid must not be empty");
        }
        if self.seeds.is_empty() {
            bail!("seeds must not be empty");
        }
        if self.pretrain_steps == 0 {
            bail!("pretrain_steps must be > 0");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_valid() {
        ExperimentConfig::full("gpt-small").validate().unwrap();
        ExperimentConfig::quick("gpt-nano").validate().unwrap();
        assert!(ExperimentConfig::profile("nope", "x").is_err());
    }

    #[test]
    fn quick_is_faster_than_full() {
        let q = ExperimentConfig::quick("m");
        let f = ExperimentConfig::full("m");
        assert!(q.pretrain_steps < f.pretrain_steps);
        assert!(q.retrain_steps < f.retrain_steps);
    }

    #[test]
    fn file_overrides() {
        let dir = std::env::temp_dir().join("perp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"retrain_steps": 7, "lr_grid": [0.5], "seeds": [9]}"#).unwrap();
        let c = ExperimentConfig::quick("gpt-nano").with_file(&p).unwrap();
        assert_eq!(c.retrain_steps, 7);
        assert_eq!(c.lr_grid, vec![0.5]);
        assert_eq!(c.seeds, vec![9]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut c = ExperimentConfig::full("gpt-small");
        c.lr_grid = vec![5e-6, 1e-3, 0.30000000000000004];
        c.pretrain_lr = 0.1 + 0.2; // not representable as a short decimal
        c.seeds = vec![0, 7, u32::MAX as u64];
        // serialize, re-parse from text, apply over an unrelated base: every
        // field (and thus every cache key) must round-trip bit-exactly
        let text = c.to_json().to_string();
        let j = Json::parse(&text).unwrap();
        let back = ExperimentConfig::quick("gpt-nano").with_json(&j).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn invalid_rejected() {
        let mut c = ExperimentConfig::quick("m");
        c.lr_grid.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn layout_field_defaults_and_validates() {
        let c = ExperimentConfig::quick("m");
        assert_eq!(c.layout, "auto");
        c.validate().unwrap();
        let mut bad = ExperimentConfig::quick("m");
        bad.layout = "coo".into();
        assert!(bad.validate().is_err());

        let dir = std::env::temp_dir().join("perp_cfg_layout_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"layout": "csr"}"#).unwrap();
        let c = ExperimentConfig::quick("gpt-nano").with_file(&p).unwrap();
        assert_eq!(c.layout, "csr");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_field_defaults_and_validates() {
        let c = ExperimentConfig::quick("m");
        assert_eq!(c.backend, "native");
        c.validate().unwrap();
        let mut bad = ExperimentConfig::quick("m");
        bad.backend = "tpu".into();
        assert!(bad.validate().is_err());

        let dir = std::env::temp_dir().join("perp_cfg_backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(&p, r#"{"backend": "pjrt"}"#).unwrap();
        let c = ExperimentConfig::quick("gpt-nano").with_file(&p).unwrap();
        assert_eq!(c.backend, "pjrt");
        std::fs::remove_dir_all(&dir).ok();
    }
}
