//! The on-disk job store: one directory per job under `<out>/jobs/`,
//! holding a single `job.json` with the job's spec (graph + resolved
//! config + knobs), lifecycle status, per-node state and final aggregate
//! rows.
//!
//! The record is the durable source of truth — the daemon's in-memory
//! queue is rebuilt from it on every boot ([`super::queue::JobManager::open`]),
//! so a kill at any point loses at most the progress since the last node
//! event (and even that is recovered for free through the stage cache:
//! committed nodes re-report as hits).  Writes go through the same
//! temp-file + rename discipline as stage artifacts, so a torn `job.json`
//! is never observed.
//!
//! The per-node `key` fields are the executor's 16-hex FNV stage keys,
//! computed once at submit time from the *resolved* config — `repro gc`
//! reads them back to pin a paused job's cache dirs as reachable roots.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::eval::MeanStd;
use crate::pipeline::{GraphReport, PlanGraph};
use crate::util::json::Json;

/// Job lifecycle.  `Queued → Running → {Done, Failed, Cancelled}`, with the
/// extra edge `Running → Queued` when a shutdown interrupts a job (it
/// resumes on the next boot through the stage cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Result<JobStatus> {
        Ok(match s {
            "queued" => JobStatus::Queued,
            "running" => JobStatus::Running,
            "done" => JobStatus::Done,
            "failed" => JobStatus::Failed,
            "cancelled" => JobStatus::Cancelled,
            other => bail!("unknown job status {other:?}"),
        })
    }

    /// Terminal states never re-enter the queue.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Per-node lifecycle within a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    Pending,
    Running,
    Done,
    Failed,
}

impl NodeStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            NodeStatus::Pending => "pending",
            NodeStatus::Running => "running",
            NodeStatus::Done => "done",
            NodeStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<NodeStatus> {
        Ok(match s {
            "pending" => NodeStatus::Pending,
            "running" => NodeStatus::Running,
            "done" => NodeStatus::Done,
            "failed" => NodeStatus::Failed,
            other => bail!("unknown node status {other:?}"),
        })
    }
}

/// One stage node's durable state: its content-address key (stable across
/// restarts — gc reachability roots), current status, and — once finished —
/// whether it came from cache and how long it took.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeState {
    pub status: NodeStatus,
    /// 16-hex FNV stage key (fixed at submit time from the resolved config)
    pub key: String,
    /// human stage label, e.g. `prune(magnitude,0.5)`
    pub label: String,
    pub cache_hit: bool,
    pub wall_s: Option<f64>,
}

/// What was submitted: the graph plus every knob the executor needs,
/// fully resolved (profile/model/layout overrides already applied) so a
/// restart re-derives bit-identical cache keys.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    pub graph: PlanGraph,
    pub cfg: ExperimentConfig,
    pub seed: u64,
    /// executor worker threads for this job's graph (`--jobs`)
    pub jobs: usize,
}

/// One aggregate node's reduced row, persisted so `GET /jobs/<id>` can
/// serve final tables without re-walking the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateSummary {
    pub name: String,
    pub over: Vec<String>,
    pub ppl: MeanStd,
    pub acc: MeanStd,
    pub sparsity: MeanStd,
}

/// The durable job record — everything `job.json` holds.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: String,
    pub spec: JobSpec,
    pub status: JobStatus,
    pub created_unix: u64,
    /// last time the job (re-)entered the queue — queue-wait is measured
    /// from here, and it advances on every shutdown-requeue
    pub queued_unix: u64,
    pub started_unix: Option<u64>,
    pub finished_unix: Option<u64>,
    pub error: Option<String>,
    /// non-fatal history (restart resumes, shutdown interrupts)
    pub warnings: Vec<String>,
    /// execution attempts (resumes increment)
    pub attempts: u64,
    /// backend executions attributed to this job's attempts (exact when
    /// jobs run one at a time; concurrent jobs on one backend overlap)
    pub backend_execs: u64,
    /// seconds the most recent attempt waited in the queue
    pub queue_wait_s: Option<f64>,
    /// wall clock of the finishing attempt
    pub wall_s: Option<f64>,
    pub nodes: BTreeMap<String, NodeState>,
    pub aggregates: Vec<AggregateSummary>,
}

impl JobRecord {
    /// Fresh queued record; node states initialised `pending` with their
    /// submit-time stage keys.
    pub fn new(id: &str, spec: JobSpec, now: u64) -> Result<JobRecord> {
        spec.graph.validate().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
        let keys = spec
            .graph
            .node_keys(&spec.cfg, spec.seed)
            .map_err(|e| anyhow::anyhow!("keying graph: {e}"))?;
        let nodes = spec
            .graph
            .nodes
            .iter()
            .filter(|n| n.stage().is_some())
            .map(|n| {
                let st = NodeState {
                    status: NodeStatus::Pending,
                    key: keys[&n.name].hex(),
                    label: n.label(),
                    cache_hit: false,
                    wall_s: None,
                };
                (n.name.clone(), st)
            })
            .collect();
        Ok(JobRecord {
            id: id.to_string(),
            spec,
            status: JobStatus::Queued,
            created_unix: now,
            queued_unix: now,
            started_unix: None,
            finished_unix: None,
            error: None,
            warnings: Vec::new(),
            attempts: 0,
            backend_execs: 0,
            queue_wait_s: None,
            wall_s: None,
            nodes: BTreeMap::new(),
            aggregates: Vec::new(),
        }
        .with_nodes(nodes))
    }

    fn with_nodes(mut self, nodes: BTreeMap<String, NodeState>) -> JobRecord {
        self.nodes = nodes;
        self
    }

    /// Reset every `running` node back to `pending` (crash/shutdown
    /// recovery: the next attempt re-checks them against the stage cache).
    pub fn reset_running_nodes(&mut self) {
        for n in self.nodes.values_mut() {
            if n.status == NodeStatus::Running {
                n.status = NodeStatus::Pending;
            }
        }
    }

    /// Fold a finished run's reports + aggregates into the node map.
    pub fn absorb_report(&mut self, report: &GraphReport) {
        for nr in &report.nodes {
            if let Some(st) = self.nodes.get_mut(&nr.name) {
                st.status = NodeStatus::Done;
                st.cache_hit = nr.rep.cache_hit;
                st.wall_s = Some(nr.rep.wall_s);
                st.key = nr.rep.key.clone();
            }
        }
        self.aggregates = report
            .aggregates
            .iter()
            .map(|a| AggregateSummary {
                name: a.name.clone(),
                over: a.over.clone(),
                ppl: a.ppl,
                acc: a.acc,
                sparsity: a.sparsity,
            })
            .collect();
    }

    pub fn nodes_done(&self) -> usize {
        self.nodes.values().filter(|n| n.status == NodeStatus::Done).count()
    }

    // ----- JSON (de)serialization ----------------------------------------

    pub fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|(name, st)| {
                (
                    name.as_str(),
                    Json::obj(vec![
                        ("status", Json::Str(st.status.as_str().to_string())),
                        ("key", Json::Str(st.key.clone())),
                        ("label", Json::Str(st.label.clone())),
                        ("cache_hit", Json::Bool(st.cache_hit)),
                        ("wall_s", opt_num(st.wall_s)),
                    ]),
                )
            })
            .collect::<Vec<_>>();
        let aggregates = self
            .aggregates
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("name", Json::Str(a.name.clone())),
                    (
                        "over",
                        Json::Arr(a.over.iter().map(|s| Json::Str(s.clone())).collect()),
                    ),
                    ("ppl", mean_std_json(&a.ppl)),
                    ("acc", mean_std_json(&a.acc)),
                    ("sparsity", mean_std_json(&a.sparsity)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("name", Json::Str(self.spec.name.clone())),
            ("status", Json::Str(self.status.as_str().to_string())),
            ("graph", self.spec.graph.to_json()),
            ("config", self.spec.cfg.to_json()),
            ("seed", Json::Num(self.spec.seed as f64)),
            ("jobs", Json::Num(self.spec.jobs as f64)),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("queued_unix", Json::Num(self.queued_unix as f64)),
            ("started_unix", opt_num(self.started_unix.map(|v| v as f64))),
            ("finished_unix", opt_num(self.finished_unix.map(|v| v as f64))),
            (
                "error",
                self.error.clone().map(Json::Str).unwrap_or(Json::Null),
            ),
            (
                "warnings",
                Json::Arr(self.warnings.iter().map(|w| Json::Str(w.clone())).collect()),
            ),
            ("attempts", Json::Num(self.attempts as f64)),
            ("backend_execs", Json::Num(self.backend_execs as f64)),
            ("queue_wait_s", opt_num(self.queue_wait_s)),
            ("wall_s", opt_num(self.wall_s)),
            ("nodes", Json::obj(nodes)),
            ("aggregates", Json::Arr(aggregates)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<JobRecord> {
        let id = j
            .get("id")
            .and_then(Json::as_str)
            .context("job record missing string \"id\"")?
            .to_string();
        let graph = PlanGraph::from_json(j.get("graph").context("job record missing \"graph\"")?)
            .map_err(|e| anyhow::anyhow!("job {id}: graph: {e}"))?;
        // the stored config is complete (to_json emits every field), so any
        // base works; quick() keeps this cheap
        let cfg = ExperimentConfig::quick("gpt-nano")
            .with_json(j.get("config").context("job record missing \"config\"")?)?;
        let spec = JobSpec {
            name: j.str_or("name", &graph.name),
            graph,
            cfg,
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
            jobs: j.get("jobs").and_then(Json::as_usize).unwrap_or(1).max(1),
        };
        let status = JobStatus::parse(
            j.get("status").and_then(Json::as_str).context("job record missing \"status\"")?,
        )?;
        let nodes = j
            .get("nodes")
            .and_then(Json::as_obj)
            .map(|m| {
                m.iter()
                    .map(|(name, nj)| {
                        let st = NodeState {
                            status: NodeStatus::parse(&nj.str_or("status", "pending"))?,
                            key: nj.str_or("key", ""),
                            label: nj.str_or("label", ""),
                            cache_hit: nj
                                .get("cache_hit")
                                .and_then(Json::as_bool)
                                .unwrap_or(false),
                            wall_s: nj.get("wall_s").and_then(Json::as_f64),
                        };
                        Ok((name.clone(), st))
                    })
                    .collect::<Result<BTreeMap<_, _>>>()
            })
            .transpose()?
            .unwrap_or_default();
        let aggregates = j
            .get("aggregates")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .map(|aj| AggregateSummary {
                        name: aj.str_or("name", ""),
                        over: aj
                            .get("over")
                            .and_then(Json::as_arr)
                            .map(|o| {
                                o.iter().filter_map(Json::as_str).map(str::to_string).collect()
                            })
                            .unwrap_or_default(),
                        ppl: mean_std_from(aj.get("ppl")),
                        acc: mean_std_from(aj.get("acc")),
                        sparsity: mean_std_from(aj.get("sparsity")),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(JobRecord {
            id,
            spec,
            status,
            created_unix: j.get("created_unix").and_then(Json::as_i64).unwrap_or(0) as u64,
            queued_unix: j.get("queued_unix").and_then(Json::as_i64).unwrap_or(0) as u64,
            started_unix: j.get("started_unix").and_then(Json::as_i64).map(|v| v as u64),
            finished_unix: j.get("finished_unix").and_then(Json::as_i64).map(|v| v as u64),
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
            warnings: j
                .get("warnings")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
                .unwrap_or_default(),
            attempts: j.get("attempts").and_then(Json::as_i64).unwrap_or(0) as u64,
            backend_execs: j.get("backend_execs").and_then(Json::as_i64).unwrap_or(0) as u64,
            queue_wait_s: j.get("queue_wait_s").and_then(Json::as_f64),
            wall_s: j.get("wall_s").and_then(Json::as_f64),
            nodes,
            aggregates,
        })
    }
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(v) if v.is_finite() => Json::Num(v),
        _ => Json::Null,
    }
}

fn mean_std_json(m: &MeanStd) -> Json {
    Json::obj(vec![
        ("mean", opt_num(Some(m.mean))),
        ("std", opt_num(Some(m.std))),
        ("n", Json::Num(m.n as f64)),
    ])
}

fn mean_std_from(j: Option<&Json>) -> MeanStd {
    let num = |key: &str| {
        j.and_then(|j| j.get(key)).and_then(Json::as_f64).unwrap_or(f64::NAN)
    };
    MeanStd {
        mean: num("mean"),
        std: num("std"),
        n: j.and_then(|j| j.get("n")).and_then(Json::as_usize).unwrap_or(0),
    }
}

/// Numeric suffix of a `jNNNN` job id (`None` for foreign names).
fn id_num(id: &str) -> Option<u64> {
    id.strip_prefix('j').and_then(|n| n.parse::<u64>().ok())
}

/// Unix seconds now (0 if the clock is before the epoch).
pub fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Directory-per-job store rooted at `<out>/jobs/`.  Cheap to clone —
/// it is just the root path; all state lives on disk.
#[derive(Debug, Clone)]
pub struct JobStore {
    root: PathBuf,
}

impl JobStore {
    pub fn open(root: &Path) -> Result<JobStore> {
        std::fs::create_dir_all(root).with_context(|| format!("creating job store {root:?}"))?;
        Ok(JobStore { root: root.to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn job_dir(&self, id: &str) -> PathBuf {
        self.root.join(id)
    }

    /// Render job id number `n` as `j0001`-style.  The padding is cosmetic:
    /// ordering everywhere goes through [`id_num`], not lexical sort.
    pub fn format_id(n: u64) -> String {
        format!("j{n:04}")
    }

    /// First unused job id number (max existing numeric suffix + 1, so ids
    /// never recycle within one store).  [`super::queue::JobManager`] seeds
    /// its serialized counter from this once at open — allocation itself
    /// must happen under the manager's lock, not by rescanning here, or two
    /// concurrent submits race to the same id.
    pub fn next_id_num(&self) -> Result<u64> {
        Ok(self.ids()?.iter().filter_map(|id| id_num(id.as_str())).max().unwrap_or(0) + 1)
    }

    /// Next job id as a string; see [`Self::next_id_num`] for the caveat
    /// that concurrent callers must serialize externally.
    pub fn allocate_id(&self) -> Result<String> {
        Ok(Self::format_id(self.next_id_num()?))
    }

    /// Every job id present on disk, oldest first.  Sorted by the parsed
    /// numeric suffix (not lexically — `j10000` must come after `j9999`),
    /// with any foreign names last.
    pub fn ids(&self) -> Result<Vec<String>> {
        let mut ids = Vec::new();
        let entries = std::fs::read_dir(&self.root)
            .with_context(|| format!("scanning job store {:?}", self.root))?;
        for e in entries {
            let e = e?;
            if e.path().join("job.json").is_file() {
                ids.push(e.file_name().to_string_lossy().to_string());
            }
        }
        ids.sort_by(|a, b| match (id_num(a), id_num(b)) {
            (Some(x), Some(y)) => x.cmp(&y),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => a.cmp(b),
        });
        Ok(ids)
    }

    // ----- cancel marker -------------------------------------------------
    //
    // An acknowledged cancel of a *running* job must survive a daemon kill
    // that lands before the worker's final save.  It can't live inside
    // `job.json`: the worker's node hook keeps overwriting that file from
    // its own in-memory copy, which would clobber a concurrently-written
    // field.  A separate marker file is immune to those overwrites; boot
    // rescan honors it and the worker clears it on any terminal save.

    fn cancel_marker(&self, id: &str) -> PathBuf {
        self.job_dir(id).join("cancel_requested")
    }

    /// Durably record that a cancel was acknowledged for this job.
    pub fn request_cancel(&self, id: &str) -> Result<()> {
        let path = self.cancel_marker(id);
        std::fs::write(&path, b"1").with_context(|| format!("writing {path:?}"))
    }

    pub fn cancel_requested(&self, id: &str) -> bool {
        self.cancel_marker(id).is_file()
    }

    pub fn clear_cancel(&self, id: &str) {
        let _ = std::fs::remove_file(self.cancel_marker(id));
    }

    pub fn save(&self, rec: &JobRecord) -> Result<()> {
        let dir = self.job_dir(&rec.id);
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        let path = dir.join("job.json");
        // same torn-write discipline as stage artifacts: unique temp name,
        // then one rename
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let unique = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = dir.join(format!(".job.json.tmp-{}-{unique}", std::process::id()));
        std::fs::write(&tmp, rec.to_json().to_string())
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    pub fn load(&self, id: &str) -> Result<JobRecord> {
        let path = self.job_dir(id).join("job.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        JobRecord::from_json(&j)
    }

    /// All records, sorted by id.
    pub fn list(&self) -> Result<Vec<JobRecord>> {
        self.ids()?.iter().map(|id| self.load(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::parse::parse_graph;

    fn spec() -> JobSpec {
        let graph = parse_graph("t", "prune(magnitude,0.5)|eval(ppl)").unwrap();
        JobSpec {
            name: "t".to_string(),
            graph,
            cfg: ExperimentConfig::quick("gpt-nano"),
            seed: 7,
            jobs: 2,
        }
    }

    #[test]
    fn record_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join(format!("perp_jobstore_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = JobStore::open(&dir).unwrap();
        let id = store.allocate_id().unwrap();
        assert_eq!(id, "j0001");
        let mut rec = JobRecord::new(&id, spec(), 1_000).unwrap();
        rec.status = JobStatus::Running;
        rec.started_unix = Some(1_010);
        rec.attempts = 2;
        rec.warnings.push("resumed after restart".to_string());
        let some_node = rec.nodes.keys().next().unwrap().clone();
        rec.nodes.get_mut(&some_node).unwrap().status = NodeStatus::Running;
        store.save(&rec).unwrap();
        let back = store.load(&id).unwrap();
        assert_eq!(back, rec);
        // ids never recycle
        assert_eq!(store.allocate_id().unwrap(), "j0002");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn node_states_initialised_pending_with_keys() {
        let rec = JobRecord::new("j0001", spec(), 0).unwrap();
        // parse_graph prepends pretrain: 3 stage nodes
        assert_eq!(rec.nodes.len(), 3);
        for st in rec.nodes.values() {
            assert_eq!(st.status, NodeStatus::Pending);
            assert_eq!(st.key.len(), 16, "FNV keys are 16 hex chars");
        }
        let keys = rec.spec.graph.node_keys(&rec.spec.cfg, rec.spec.seed).unwrap();
        for (name, st) in &rec.nodes {
            assert_eq!(st.key, keys[name].hex());
        }
    }

    #[test]
    fn ids_sort_numerically_past_padding_width() {
        let dir = std::env::temp_dir().join(format!("perp_jobstore_pad_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = JobStore::open(&dir).unwrap();
        for n in [9999u64, 123, 10000, 1] {
            let rec = JobRecord::new(&JobStore::format_id(n), spec(), 0).unwrap();
            store.save(&rec).unwrap();
        }
        // lexically "j10000" < "j9999"; FIFO ordering must be numeric
        assert_eq!(store.ids().unwrap(), ["j0001", "j0123", "j9999", "j10000"]);
        assert_eq!(store.allocate_id().unwrap(), "j10001");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cancel_marker_roundtrip() {
        let dir = std::env::temp_dir().join(format!("perp_jobstore_cm_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let store = JobStore::open(&dir).unwrap();
        let rec = JobRecord::new("j0001", spec(), 0).unwrap();
        store.save(&rec).unwrap();
        assert!(!store.cancel_requested("j0001"));
        store.request_cancel("j0001").unwrap();
        assert!(store.cancel_requested("j0001"));
        store.clear_cancel("j0001");
        assert!(!store.cancel_requested("j0001"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reset_running_nodes_for_resume() {
        let mut rec = JobRecord::new("j0001", spec(), 0).unwrap();
        let names: Vec<String> = rec.nodes.keys().cloned().collect();
        rec.nodes.get_mut(&names[0]).unwrap().status = NodeStatus::Running;
        rec.nodes.get_mut(&names[1]).unwrap().status = NodeStatus::Done;
        rec.reset_running_nodes();
        assert_eq!(rec.nodes[&names[0]].status, NodeStatus::Pending);
        assert_eq!(rec.nodes[&names[1]].status, NodeStatus::Done);
    }
}
