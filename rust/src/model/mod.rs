//! Host-side model state: the parameter store, initialisation and
//! checkpointing.
//!
//! The rust coordinator owns every tensor between PJRT executions; the
//! manifest (see [`crate::runtime::manifest`]) defines names, shapes and
//! group membership.  This module is deliberately dumb about *semantics* —
//! the training graphs live in L2 — and strict about *bookkeeping*:
//! shape-checked updates, group queries, sparsity accounting.

pub mod init;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ModelManifest;
use crate::tensor::{io, Tensor};

/// Named parameter tensors matching the manifest inventory exactly.
#[derive(Debug, Clone)]
pub struct ParamStore {
    tensors: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Zero-filled store (tests / loading targets).
    pub fn zeros(mm: &ModelManifest) -> ParamStore {
        let tensors = mm
            .params
            .iter()
            .map(|p| (p.name.clone(), Tensor::zeros(&p.shape)))
            .collect();
        ParamStore { tensors }
    }

    pub fn from_map(mm: &ModelManifest, tensors: BTreeMap<String, Tensor>) -> Result<ParamStore> {
        for p in &mm.params {
            match tensors.get(&p.name) {
                None => bail!("checkpoint missing parameter {:?}", p.name),
                Some(t) if t.shape() != &p.shape[..] => bail!(
                    "checkpoint shape mismatch for {:?}: {:?} vs {:?}",
                    p.name,
                    t.shape(),
                    p.shape
                ),
                _ => {}
            }
        }
        if tensors.len() != mm.params.len() {
            bail!(
                "checkpoint has {} tensors, manifest wants {}",
                tensors.len(),
                mm.params.len()
            );
        }
        Ok(ParamStore { tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown parameter {name:?}"))
    }

    pub fn try_get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        let old = self
            .tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown parameter {name:?}"));
        assert_eq!(old.shape(), t.shape(), "shape change on {name:?}");
        self.tensors.insert(name.to_string(), t);
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tensors.keys()
    }

    pub fn map(&self) -> &BTreeMap<String, Tensor> {
        &self.tensors
    }

    /// Zero out pruned entries of every prunable weight in place.
    pub fn apply_masks(&mut self, masks: &BTreeMap<String, Tensor>) {
        for (name, mask) in masks {
            let w = self.get(name).hadamard(mask);
            self.set(name, w);
        }
    }

    /// Overall fraction of zero entries across the prunable weights.
    pub fn weight_sparsity(&self, mm: &ModelManifest) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for name in &mm.prunable {
            let t = self.get(name);
            zeros += t.count(|x| x == 0.0);
            total += t.numel();
        }
        zeros as f64 / total.max(1) as f64
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        io::save(path, &self.tensors).context("saving checkpoint")
    }

    pub fn load(mm: &ModelManifest, path: &Path) -> Result<ParamStore> {
        let tensors = io::load(path).context("loading checkpoint")?;
        ParamStore::from_map(mm, tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn nano() -> ModelManifest {
        Manifest::builtin().model("gpt-nano").unwrap().clone()
    }

    #[test]
    fn zeros_matches_manifest() {
        let mm = nano();
        let ps = ParamStore::zeros(&mm);
        assert_eq!(ps.names().count(), mm.params.len());
        for p in &mm.params {
            assert_eq!(ps.get(&p.name).shape(), &p.shape[..]);
        }
    }

    #[test]
    fn masks_apply_and_sparsity_counts() {
        let mm = nano();
        let mut rng = crate::util::rng::Rng::new(1);
        let mut ps = init::init_params(&mm, &mut rng);
        let mut masks = BTreeMap::new();
        for n in &mm.prunable {
            let shape = mm.param_shape(n).to_vec();
            let mut m = Tensor::ones(&shape);
            for x in m.data_mut().iter_mut().step_by(2) {
                *x = 0.0;
            }
            masks.insert(n.clone(), m);
        }
        ps.apply_masks(&masks);
        let s = ps.weight_sparsity(&mm);
        assert!((s - 0.5).abs() < 0.01, "{s}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mm = nano();
        let mut rng = crate::util::rng::Rng::new(2);
        let ps = init::init_params(&mm, &mut rng);
        let dir = std::env::temp_dir().join("perp_store_test");
        let path = dir.join("m.ptns");
        ps.save(&path).unwrap();
        let ps2 = ParamStore::load(&mm, &path).unwrap();
        for n in ps.names() {
            assert_eq!(ps.get(n), ps2.get(n));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_shapes() {
        let mm = nano();
        let mut map = ParamStore::zeros(&mm).tensors;
        map.insert("head_w".into(), Tensor::zeros(&[1, 1]));
        assert!(ParamStore::from_map(&mm, map).is_err());
    }
}
