//! The dynamic request batcher: one engine thread per served model variant.
//!
//! HTTP workers hand [`Work`] items to the engine over a channel; the
//! engine owns the backend, the session (weights/masks/tokenizer) and the
//! per-stream [`KvCache`] slots, and runs the serving loop:
//!
//! 1. **intake** — drain queued requests (blocking only when fully idle);
//! 2. **admit** — assign free KV slots to waiting requests (up to
//!    `max_active`) and run one padded `prefill` batch over the wave;
//! 3. **decode** — lock-step every active stream one token forward through
//!    `decode_step`, writing the returned K/V rows into each stream's slot
//!    and early-exiting streams on EOS / length / cache-full.
//!
//! New requests join between decode steps (continuous batching), so a
//! long-running stream never blocks admission, and a `max_active = 1`
//! engine degrades to the sequential baseline `bench-serve` compares
//! against.  The engine thread is the only place model state lives —
//! backends keep their interior-mutability (`!Sync`) and the HTTP layer
//! stays a thin codec.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, bail, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::sweep::ExpContext;
use crate::coordinator::Session;
use crate::data::tokenizer::PAD;
use crate::data::Tokenizer;
use crate::runtime::{default_artifacts_dir, open_backend, BackendKind};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::kv::{self, KvCache};
use super::spec::{RoundInput, SpecEngine};

// ---------------------------------------------------------------------------
// Requests and results.
// ---------------------------------------------------------------------------

pub struct GenRequest {
    pub prompt: String,
    /// Requested new tokens; clamped to [1, seq_len - prompt_len].
    pub max_new: Option<usize>,
    /// 0 = greedy argmax; > 0 = softmax sampling at this temperature.
    pub temperature: f32,
    /// Submission time — the `serve.queue.wait_ms` histogram measures from
    /// here to KV-slot admission.
    pub enqueued: std::time::Instant,
    pub reply: Sender<GenResult>,
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub completion: String,
    pub tokens: Vec<i32>,
    pub prompt_tokens: usize,
    /// "eos" | "length"
    pub finish: &'static str,
}

#[derive(Debug, Clone)]
pub struct ScoreResult {
    /// Mean next-token NLL over the scored positions.
    pub nll: f64,
    pub ppl: f64,
    pub tokens: usize,
}

pub enum Work {
    Gen(GenRequest),
    Score { text: String, reply: Sender<Result<ScoreResult, String>> },
    Shutdown,
}

// ---------------------------------------------------------------------------
// Engine configuration, metrics and handle.
// ---------------------------------------------------------------------------

/// Batcher knobs (documented in rust/README.md § Serving).
#[derive(Debug, Clone)]
pub struct BatchCfg {
    /// Concurrent decode streams; clamped to the model's `serve_slots`.
    /// 1 = the sequential (batch = 1) baseline.
    pub max_active: usize,
    /// Default per-request new-token budget when the client sends none.
    pub max_new_default: usize,
    /// EOS sampled before this many emitted tokens is kept as a regular
    /// token, so completions are never empty.
    pub min_tokens: usize,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg { max_active: usize::MAX, max_new_default: 16, min_tokens: 1 }
    }
}

#[derive(Default)]
pub struct EngineMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub gen_tokens: AtomicU64,
    pub prefills: AtomicU64,
    pub decode_steps: AtomicU64,
    /// Requests accepted but not yet assigned a KV slot.
    pub queued: AtomicU64,
    pub active: AtomicU64,
    pub peak_active: AtomicU64,
    /// Speculative rounds (draft + verify pairs) — 0 when no draft is
    /// configured.
    pub spec_rounds: AtomicU64,
    /// Batched draft decode steps across all rounds.
    pub spec_draft_steps: AtomicU64,
    pub spec_proposed: AtomicU64,
    pub spec_accepted: AtomicU64,
    pub spec_rejected: AtomicU64,
    /// Rounds × streams where some proposal was refused and the KV planes
    /// rolled back.
    pub spec_rollbacks: AtomicU64,
}

/// Static facts about a spawned engine (for `/models` and `/healthz`).
#[derive(Debug, Clone)]
pub struct EngineInfo {
    pub total_params: usize,
    pub weight_sparsity: f64,
    pub slots: usize,
    pub max_active: usize,
    pub seq_len: usize,
    pub kv_bytes: usize,
    /// Compressed weight bytes across layers routed to a compressed layout
    /// (CSR/BSR, exact or quantised; 0 = none routed).
    pub sparse_bytes: usize,
    pub checkpoint: Option<String>,
    /// Draft checkpoint when speculative decoding is on.
    pub draft: Option<String>,
    /// Draft sparsity (0 when no draft).
    pub draft_sparsity: f64,
    /// Effective draft length (0 = speculation disabled).
    pub spec_k: usize,
}

/// Everything needed to bring one model variant up.
pub struct EngineSpec {
    pub name: String,
    pub cfg: ExperimentConfig,
    pub seed: u64,
    /// Checkpoint to serve; `None` falls back to the cached dense pretrain
    /// (pretraining on cache miss, exactly like the sweeps).
    pub checkpoint: Option<PathBuf>,
    /// Dense-checkpoint cache directory (`<out>/cache`).
    pub cache_dir: PathBuf,
    pub batch: BatchCfg,
    /// Draft checkpoint for speculative decoding (same architecture as the
    /// target; typically a `prune|retrain|merge` product).  `None` = plain
    /// decoding.
    pub draft: Option<PathBuf>,
    /// Draft tokens per round; clamped to `spec_width - 1`.
    pub spec_k: usize,
}

pub struct EngineHandle {
    pub name: String,
    pub model: String,
    pub metrics: Arc<EngineMetrics>,
    pub info: EngineInfo,
    tx: Mutex<Sender<Work>>,
}

impl EngineHandle {
    fn submit(&self, w: Work) -> Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(w)
            .map_err(|_| anyhow!("engine thread is gone"))
    }

    /// Enqueue a generation request and block until its stream completes.
    pub fn generate(
        &self,
        prompt: String,
        max_new: Option<usize>,
        temperature: f32,
    ) -> Result<GenResult> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.queued.fetch_add(1, Ordering::Relaxed);
        self.submit(Work::Gen(GenRequest {
            prompt,
            max_new,
            temperature,
            enqueued: std::time::Instant::now(),
            reply: tx,
        }))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the request"))
    }

    /// Score a text's per-token NLL through the `score` executable.
    pub fn score(&self, text: String) -> Result<ScoreResult> {
        let (tx, rx) = mpsc::channel();
        self.submit(Work::Score { text, reply: tx })?;
        rx.recv()
            .map_err(|_| anyhow!("engine dropped the request"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn shutdown(&self) {
        let _ = self.submit(Work::Shutdown);
    }
}

/// Spawn the engine thread and block until its session is ready (the dense
/// fallback may pretrain on a cache miss, so this can take a while on the
/// first boot of a model).
pub fn spawn(spec: EngineSpec) -> Result<Arc<EngineHandle>> {
    let (work_tx, work_rx) = mpsc::channel::<Work>();
    let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<EngineInfo, String>>();
    let metrics = Arc::new(EngineMetrics::default());
    let thread_metrics = metrics.clone();
    let name = spec.name.clone();
    let model = spec.cfg.model.clone();
    thread::Builder::new()
        .name(format!("engine-{name}"))
        .spawn(move || engine_main(spec, work_rx, ready_tx, thread_metrics))?;
    let info = ready_rx
        .recv()
        .map_err(|_| anyhow!("engine thread died during startup"))?
        .map_err(|e| anyhow!("engine startup failed: {e}"))?;
    Ok(Arc::new(EngineHandle { name, model, metrics, info, tx: Mutex::new(work_tx) }))
}

// ---------------------------------------------------------------------------
// The engine thread.
// ---------------------------------------------------------------------------

fn engine_main(
    spec: EngineSpec,
    rx: Receiver<Work>,
    ready: Sender<std::result::Result<EngineInfo, String>>,
    metrics: Arc<EngineMetrics>,
) {
    let kind = match BackendKind::parse(&spec.cfg.backend) {
        Ok(k) => k,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let backend = match open_backend(kind, &default_artifacts_dir()) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    let session = match &spec.checkpoint {
        Some(path) => Session::from_checkpoint(backend.as_ref(), spec.cfg.clone(), spec.seed, path),
        None => ExpContext::new(backend.as_ref(), spec.cfg.clone(), spec.cache_dir.clone())
            .dense_session(spec.seed),
    };
    let s = match session {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // the draft shares the backend and the architecture; only its weights
    // (typically a prune|retrain|merge product) differ
    let draft = match &spec.draft {
        None => None,
        Some(path) => {
            match Session::from_checkpoint(backend.as_ref(), spec.cfg.clone(), spec.seed, path) {
                Ok(d) => Some(d),
                Err(e) => {
                    let _ = ready.send(Err(format!("loading draft {}: {e:#}", path.display())));
                    return;
                }
            }
        }
    };
    let cfg = &s.mm.cfg;
    let max_active = spec.batch.max_active.clamp(1, cfg.serve_slots);
    let spec_k = if draft.is_some() {
        spec.spec_k.clamp(1, cfg.spec_width.saturating_sub(1).max(1))
    } else {
        0
    };
    let info = EngineInfo {
        total_params: s.mm.total_params(),
        weight_sparsity: s.params.weight_sparsity(&s.mm),
        slots: cfg.serve_slots,
        max_active,
        seq_len: cfg.seq_len,
        kv_bytes: kv::kv_bytes(cfg),
        sparse_bytes: s.sparse.compressed_bytes(),
        checkpoint: spec.checkpoint.as_ref().map(|p| p.display().to_string()),
        draft: spec.draft.as_ref().map(|p| p.display().to_string()),
        draft_sparsity: draft.as_ref().map_or(0.0, |d| d.params.weight_sparsity(&d.mm)),
        spec_k,
    };
    if ready.send(Ok(info)).is_err() {
        return; // spawner gave up
    }
    crate::info!(
        "engine {}: serving {} (sparsity {:.3}, {} slots, max_active {}{})",
        spec.name,
        cfg.name,
        s.params.weight_sparsity(&s.mm),
        cfg.serve_slots,
        max_active,
        match &draft {
            Some(d) => format!(
                ", spec k={} draft sparsity {:.3}",
                spec_k,
                d.params.weight_sparsity(&d.mm)
            ),
            None => String::new(),
        }
    );
    run_loop(&spec, &s, draft.as_ref(), spec_k, rx, &metrics, max_active);
}

struct Stream {
    /// Valid cache rows; also the position index the next decode writes.
    pos: usize,
    /// Last sampled token — the next decode step's input.
    last: i32,
    out: Vec<i32>,
    max_new: usize,
    temperature: f32,
    prompt_tokens: usize,
    reply: Sender<GenResult>,
}

fn run_loop(
    spec: &EngineSpec,
    s: &Session,
    draft: Option<&Session>,
    spec_k: usize,
    rx: Receiver<Work>,
    metrics: &EngineMetrics,
    max_active: usize,
) {
    let mm = &s.mm;
    let cfg = &mm.cfg;
    let (slots, seq, vocab) = (cfg.serve_slots, cfg.seq_len, cfg.vocab);
    let sw = cfg.spec_width;
    let eos = s.tokenizer.eos();
    let min_tokens = spec.batch.min_tokens;
    let mut cache = KvCache::new(cfg);
    // greedy streams run draft-verify rounds through this; sampling
    // streams (and everything when no draft is loaded) take plain decode
    let mut speceng: Option<SpecEngine> =
        if spec_k > 0 && draft.is_some() { Some(SpecEngine::new(cfg, spec_k)) } else { None };
    let spec_tokens_shape = [slots, sw];
    let mut streams: Vec<Option<Stream>> = (0..slots).map(|_| None).collect();
    let mut pending: VecDeque<GenRequest> = VecDeque::new();
    type ScoreReply = Sender<std::result::Result<ScoreResult, String>>;
    let mut pending_scores: VecDeque<(String, ScoreReply)> = VecDeque::new();
    let mut rng = Rng::new(spec.seed ^ 0x5EAF);
    let slot_shape = [slots];
    let prefill_shape = [slots, seq];
    let mut step_tokens = vec![0i32; slots];
    let mut step_pos = vec![-1i32; slots];

    'outer: loop {
        // ---- 1. intake -------------------------------------------------
        let mut block = pending.is_empty()
            && pending_scores.is_empty()
            && streams.iter().all(Option::is_none);
        loop {
            let w = if block {
                block = false;
                match rx.recv() {
                    Ok(w) => w,
                    Err(_) => break 'outer,
                }
            } else {
                match rx.try_recv() {
                    Ok(w) => w,
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            };
            match w {
                Work::Gen(req) => pending.push_back(req),
                // deferred: a full score forward between every decode step
                // would stall all active streams, so at most one runs per
                // loop iteration, after the decode step
                Work::Score { text, reply } => pending_scores.push_back((text, reply)),
                Work::Shutdown => break 'outer,
            }
        }

        // ---- 2. admit a wave of new streams + prefill ------------------
        let active = streams.iter().filter(|x| x.is_some()).count();
        let headroom = max_active.saturating_sub(active).min(cache.free_slots());
        if headroom > 0 && !pending.is_empty() {
            let mut admitted: Vec<usize> = Vec::new();
            let mut ptoks = vec![PAD; slots * seq];
            let mut lens = vec![0i32; slots];
            while admitted.len() < headroom {
                let Some(req) = pending.pop_front() else { break };
                metrics.queued.fetch_sub(1, Ordering::Relaxed);
                crate::obs::counters::Registry::global()
                    .observe("serve.queue.wait_ms", req.enqueued.elapsed().as_secs_f64() * 1e3);
                let slot = cache.alloc().expect("headroom implies a free slot");
                // leave at least one position for generation
                let ids = s.tokenizer.encode_prompt(&req.prompt, seq - 1);
                ptoks[slot * seq..slot * seq + ids.len()].copy_from_slice(&ids);
                lens[slot] = ids.len() as i32;
                let cap = seq - ids.len();
                let max_new =
                    req.max_new.unwrap_or(spec.batch.max_new_default).clamp(1, cap);
                streams[slot] = Some(Stream {
                    pos: ids.len(),
                    last: 0,
                    out: Vec::new(),
                    max_new,
                    temperature: req.temperature,
                    prompt_tokens: ids.len(),
                    reply: req.reply,
                });
                admitted.push(slot);
            }
            metrics.prefills.fetch_add(1, Ordering::Relaxed);
            let run = {
                let _sp = crate::span!("serve", "prefill").arg("admitted", admitted.len());
                let feed = s
                    .feed()
                    .ints("tokens", &prefill_shape, &ptoks)
                    .ints("lens", &slot_shape, &lens);
                s.rt.run(&cfg.name, "prefill", &feed)
            };
            match run {
                Err(e) => {
                    crate::warn!("prefill failed: {e:#}");
                    for slot in admitted {
                        streams[slot] = None; // dropped reply -> client error
                        cache.release(slot);
                    }
                }
                Ok(out) => {
                    for layer in 0..cache.n_layers() {
                        let k = out.get(&format!("k::h{layer}"));
                        let v = out.get(&format!("v::h{layer}"));
                        for &slot in &admitted {
                            cache.adopt_prefill(slot, layer, k, v);
                        }
                    }
                    // draft prefill for the greedy admits — same prompts,
                    // same slot indices, into the spec engine's planes.
                    // A failure only downgrades those streams to plain
                    // decode; the target path is unaffected.
                    if let (Some(sp), Some(ds)) = (speceng.as_mut(), draft) {
                        let greedy: Vec<usize> = admitted
                            .iter()
                            .copied()
                            .filter(|&sl| {
                                streams[sl].as_ref().is_some_and(|st| st.temperature <= 0.0)
                            })
                            .collect();
                        if !greedy.is_empty() {
                            let run = {
                                let _sp = crate::span!("spec", "draft_prefill")
                                    .arg("admitted", greedy.len());
                                let feed = ds
                                    .feed()
                                    .ints("tokens", &prefill_shape, &ptoks)
                                    .ints("lens", &slot_shape, &lens);
                                ds.rt.run(&cfg.name, "prefill", &feed)
                            };
                            match run {
                                Err(e) => {
                                    crate::warn!(
                                        "draft prefill failed (streams fall back to plain decode): {e:#}"
                                    );
                                }
                                Ok(dout) => {
                                    let dc = sp.draft_cache();
                                    for layer in 0..dc.n_layers() {
                                        let k = dout.get(&format!("k::h{layer}"));
                                        let v = dout.get(&format!("v::h{layer}"));
                                        for &slot in &greedy {
                                            dc.adopt_prefill(slot, layer, k, v);
                                        }
                                    }
                                    for &slot in &greedy {
                                        sp.admit(slot, lens[slot] as usize);
                                    }
                                }
                            }
                        }
                    }
                    let logits = out.get("logits");
                    for &slot in &admitted {
                        let st = streams[slot].as_mut().expect("just admitted");
                        let tok = sample(
                            &logits.data()[slot * vocab..(slot + 1) * vocab],
                            st.temperature,
                            &mut rng,
                        );
                        let before = st.out.len();
                        let done = advance(st, tok, eos, min_tokens, seq);
                        metrics
                            .gen_tokens
                            .fetch_add((st.out.len() - before) as u64, Ordering::Relaxed);
                        if let Some(reason) = done {
                            if let Some(sp) = speceng.as_mut() {
                                sp.release(slot);
                            }
                            finish_stream(&mut streams, slot, &mut cache, &s.tokenizer, reason, metrics);
                        }
                    }
                }
            }
        }
        let active = streams.iter().filter(|x| x.is_some()).count() as u64;
        metrics.active.store(active, Ordering::Relaxed);
        metrics.peak_active.fetch_max(active, Ordering::Relaxed);

        // ---- 3. at most one deferred /score per iteration ---------------
        if let Some((text, reply)) = pending_scores.pop_front() {
            let _ = reply.send(score_text(s, &text).map_err(|e| format!("{e:#}")));
        }

        // ---- 4. one lock-step decode over the active streams -----------
        // Spec-tracked streams (greedy, draft prefill adopted) take a
        // draft-verify round; everything else takes the plain decode step.
        // Both batches coexist in one loop iteration, so sampling streams
        // keep continuous batching while greedy ones speculate.
        if active == 0 {
            continue;
        }
        let mut spec_inputs: Vec<RoundInput> = Vec::new();
        for b in 0..slots {
            step_tokens[b] = 0;
            step_pos[b] = -1;
            if let Some(st) = &streams[b] {
                if speceng.as_ref().is_some_and(|sp| sp.tracks(b)) {
                    spec_inputs.push(RoundInput { slot: b, pos: st.pos, last: st.last });
                } else {
                    step_tokens[b] = st.last;
                    step_pos[b] = st.pos as i32;
                }
            }
        }
        {
            // per-step occupancy distributions: batch fill (decoding
            // streams) and resident KV slots, for `/metrics` histograms
            let reg = crate::obs::counters::Registry::global();
            reg.observe("serve.batch.fill", active as f64);
            reg.observe("serve.kv.occupied", cache.occupied() as f64);
        }
        if step_pos.iter().any(|&p| p >= 0) {
            let run = {
                let _sp = crate::span!("serve", "decode_step").arg("active", active);
                let mut feed = s
                    .feed()
                    .ints("tokens", &slot_shape, &step_tokens)
                    .ints("pos", &slot_shape, &step_pos);
                for layer in 0..cache.n_layers() {
                    feed = feed
                        .owned_key(format!("k::h{layer}"), &cache.k[layer])
                        .owned_key(format!("v::h{layer}"), &cache.v[layer]);
                }
                s.rt.run(&cfg.name, "decode_step", &feed)
            };
            match run {
                Err(e) => {
                    crate::warn!("decode_step failed: {e:#}");
                    for b in 0..slots {
                        if step_pos[b] >= 0 && streams[b].is_some() {
                            streams[b] = None;
                            cache.release(b);
                        }
                    }
                }
                Ok(out) => {
                    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
                    for layer in 0..cache.n_layers() {
                        let kn = out.get(&format!("knew::h{layer}"));
                        let vn = out.get(&format!("vnew::h{layer}"));
                        for b in 0..slots {
                            if step_pos[b] < 0 {
                                continue;
                            }
                            if let Some(st) = &streams[b] {
                                cache.write_new(b, st.pos, layer, kn, vn);
                            }
                        }
                    }
                    let logits = out.get("logits");
                    for b in 0..slots {
                        if step_pos[b] < 0 {
                            continue;
                        }
                        let Some(st) = streams[b].as_mut() else { continue };
                        st.pos += 1;
                        let tok = sample(
                            &logits.data()[b * vocab..(b + 1) * vocab],
                            st.temperature,
                            &mut rng,
                        );
                        let before = st.out.len();
                        let done = advance(st, tok, eos, min_tokens, seq);
                        metrics
                            .gen_tokens
                            .fetch_add((st.out.len() - before) as u64, Ordering::Relaxed);
                        if let Some(reason) = done {
                            finish_stream(&mut streams, b, &mut cache, &s.tokenizer, reason, metrics);
                        }
                    }
                }
            }
        }

        // ---- 5. one speculative round over the spec-tracked streams ----
        if let (Some(sp), Some(ds), false) =
            (speceng.as_mut(), draft, spec_inputs.is_empty())
        {
            let round = sp.round(
                &mut cache,
                &spec_inputs,
                |dc, toks, pos| {
                    let mut feed = ds
                        .feed()
                        .ints("tokens", &slot_shape, toks)
                        .ints("pos", &slot_shape, pos);
                    for layer in 0..dc.n_layers() {
                        feed = feed
                            .owned_key(format!("k::h{layer}"), &dc.k[layer])
                            .owned_key(format!("v::h{layer}"), &dc.v[layer]);
                    }
                    ds.rt.run(&cfg.name, "decode_step", &feed)
                },
                |tc, toks, pos, klen| {
                    let mut feed = s
                        .feed()
                        .ints("tokens", &spec_tokens_shape, toks)
                        .ints("pos", &slot_shape, pos)
                        .ints("klen", &slot_shape, klen);
                    for layer in 0..tc.n_layers() {
                        feed = feed
                            .owned_key(format!("k::h{layer}"), &tc.k[layer])
                            .owned_key(format!("v::h{layer}"), &tc.v[layer]);
                    }
                    s.rt.run(&cfg.name, "verify_step", &feed)
                },
            );
            match round {
                Err(e) => {
                    crate::warn!("spec round failed: {e:#}");
                    for inp in &spec_inputs {
                        if streams[inp.slot].is_some() {
                            streams[inp.slot] = None;
                            sp.release(inp.slot);
                            cache.release(inp.slot);
                        }
                    }
                }
                Ok((results, stats)) => {
                    metrics.spec_rounds.fetch_add(1, Ordering::Relaxed);
                    metrics.spec_draft_steps.fetch_add(stats.draft_steps, Ordering::Relaxed);
                    metrics.spec_proposed.fetch_add(stats.proposed, Ordering::Relaxed);
                    metrics.spec_accepted.fetch_add(stats.accepted, Ordering::Relaxed);
                    metrics.spec_rejected.fetch_add(stats.rejected, Ordering::Relaxed);
                    metrics.spec_rollbacks.fetch_add(stats.rollbacks, Ordering::Relaxed);
                    for r in results {
                        let Some(st) = streams[r.slot].as_mut() else { continue };
                        let p = st.pos;
                        let before = st.out.len();
                        let mut finished = None;
                        for (i, &tok) in r.committed.iter().enumerate() {
                            // valid cache rows after token i becomes
                            // context — keeps advance's cache-full check
                            // firing exactly where plain decode would
                            st.pos = p + i + 1;
                            if let Some(reason) = advance(st, tok, eos, min_tokens, seq) {
                                finished = Some(reason);
                                break;
                            }
                        }
                        metrics
                            .gen_tokens
                            .fetch_add((st.out.len() - before) as u64, Ordering::Relaxed);
                        if let Some(reason) = finished {
                            sp.release(r.slot);
                            finish_stream(&mut streams, r.slot, &mut cache, &s.tokenizer, reason, metrics);
                        }
                    }
                }
            }
        }
    }
    metrics.active.store(0, Ordering::Relaxed);
    // pending replies drop here; blocked clients observe a closed channel
}

/// Accept one sampled token into the stream; `Some(reason)` ends it.
fn advance(
    st: &mut Stream,
    tok: i32,
    eos: i32,
    min_tokens: usize,
    seq: usize,
) -> Option<&'static str> {
    if tok == eos && st.out.len() >= min_tokens {
        return Some("eos"); // the EOS token itself is not emitted
    }
    st.out.push(tok);
    st.last = tok;
    if st.out.len() >= st.max_new {
        return Some("length");
    }
    if st.pos >= seq {
        return Some("length"); // cache full — nowhere to write the next K/V
    }
    None
}

fn finish_stream(
    streams: &mut [Option<Stream>],
    slot: usize,
    cache: &mut KvCache,
    tokenizer: &Tokenizer,
    reason: &'static str,
    metrics: &EngineMetrics,
) {
    let st = streams[slot].take().expect("finishing an empty slot");
    cache.release(slot);
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    let completion = tokenizer.decode(&st.out);
    let _ = st.reply.send(GenResult {
        completion,
        tokens: st.out,
        prompt_tokens: st.prompt_tokens,
        finish: reason,
    });
}

/// Greedy argmax at temperature 0, softmax sampling otherwise.
fn sample(row: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return argmax(row);
    }
    let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let exps: Vec<f32> = row.iter().map(|&x| ((x - mx) / temperature).exp()).collect();
    let total: f32 = exps.iter().sum();
    let mut target = rng.f32() * total;
    for (i, &e) in exps.iter().enumerate() {
        target -= e;
        if target <= 0.0 {
            return i as i32;
        }
    }
    (row.len() - 1) as i32
}

/// First-maximum argmax — the greedy decode rule shared with the parity
/// test's full-forward reference.
pub fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// `/score`: mean next-token NLL of one text through the batched `score`
/// executable (row 0 carries the text, the pad rows are masked out).
fn score_text(s: &Session, text: &str) -> Result<ScoreResult> {
    let mm = &s.mm;
    let (b, sl) = (mm.cfg.eval_batch, mm.cfg.seq_len);
    let ids = s.tokenizer.encode_prompt(text, sl);
    if ids.len() < 2 {
        bail!("text too short to score (needs at least one non-BOS token)");
    }
    let mut tokens = vec![PAD; b * sl];
    tokens[..ids.len()].copy_from_slice(&ids);
    let mut tmask = vec![0.0f32; b * sl];
    for m in tmask.iter_mut().take(ids.len()).skip(1) {
        *m = 1.0;
    }
    let shape = [b, sl];
    let out = {
        let feed = s
            .feed()
            .ints("tokens", &shape, &tokens)
            .owned("tmask", Tensor::new(&[b, sl], tmask));
        s.rt.run(&mm.cfg.name, "score", &feed)?
    };
    let sc = out.get("scores").data()[0] as f64;
    let cnt = out.get("counts").data()[0] as f64;
    let nll = if cnt > 0.0 { -sc / cnt } else { 0.0 };
    Ok(ScoreResult { nll, ppl: nll.exp(), tokens: cnt as usize })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_takes_first_maximum() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.1, 0.9, 0.5], 0.0, &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_stays_in_range() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = sample(&[0.0, 1.0, 2.0, 3.0], 0.8, &mut rng);
            assert!((0..4).contains(&t));
        }
    }
}
