//! Checkpoint serialization: a minimal named-tensor container ("PTNS").
//!
//! Layout (little-endian):
//! ```text
//! magic "PTNS1\n" | u32 n_entries |
//!   per entry: u32 name_len | name bytes | u32 ndim | u64 dims... | f32 data...
//! ```
//! Used for model checkpoints, masks and optimizer state.  Integrity is
//! checked on load (magic, lengths, EOF).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

const MAGIC: &[u8; 6] = b"PTNS1\n";

pub fn save(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // write to a sibling temp file, then rename: concurrent readers (tests
    // sharing a checkpoint cache) never observe a half-written file
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let unique = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp{}-{unique}", std::process::id()));
    let file = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {tmp:?}"))?;
    let mut w = std::io::BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        w.write_all(&(nb.len() as u32).to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // SAFETY-free path: serialise f32s explicitly
        let mut buf = Vec::with_capacity(t.numel() * 4);
        for &x in t.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    drop(w);
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

pub fn load(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let mut magic = [0u8; 6];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?} — not a PTNS checkpoint");
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("{path:?}: corrupt name length {name_len}");
        }
        let mut nb = vec![0u8; name_len];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb).context("tensor name not utf8")?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 8 {
            bail!("{path:?}: corrupt ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut buf = vec![0u8; numel * 4];
        r.read_exact(&mut buf)?;
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::new(&shape, data));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), Tensor::randn(&[3, 4], 1.0, &mut rng));
        m.insert("b".to_string(), Tensor::randn(&[7], 0.1, &mut rng));
        m.insert("scalar".to_string(), Tensor::scalar(3.25));
        let dir = std::env::temp_dir().join("perp_io_test");
        let path = dir.join("ckpt.ptns");
        save(&path, &m).unwrap();
        let m2 = load(&path).unwrap();
        assert_eq!(m, m2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("perp_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ptns");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/x.ptns")).is_err());
    }
}
