//! `repro` — the PERP launcher.
//!
//! ```text
//! repro info                                      # models, executables, memory table
//! repro pretrain  --model gpt-nano --steps 200    # converge + cache dense weights
//! repro prune     --model gpt-nano --criterion wanda --sparsity 0.5
//! repro retrain   --model gpt-nano --mode masklora --steps 100
//! repro reconstruct --model gpt-nano --criterion magnitude --sparsity 0.5
//! repro eval      --model gpt-nano
//! repro sweep     --exp table1 [--model gpt-small] [--profile quick|full]
//! repro tables    [--profile quick]               # regenerate everything
//! ```
//!
//! All state flows through the cache directory (`--out`, default `results/`):
//! pretrained checkpoints are reused across invocations and sweeps.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use perp::config::ExperimentConfig;
use perp::coordinator::reconstruct::{self, ReconMode};
use perp::coordinator::sweep::{self, ExpContext};
use perp::peft::Mode;
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{default_artifacts_dir, open_backend, Backend, BackendKind};
use perp::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "info" => info(args),
        "pretrain" => pretrain(args),
        "prune" => prune(args),
        "retrain" => retrain(args),
        "reconstruct" => reconstruct_cmd(args),
        "eval" => eval_cmd(args),
        "sweep" => sweep_cmd(args),
        "tables" => tables(args),
        other => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
repro — PERP: Parameter-Efficient Retraining after Pruning (reproduction)

subcommands:
  info          list models, executables and the analytical memory table
  pretrain      converge a dense model and cache the checkpoint
  prune         prune the cached dense model, report ppl collapse
  retrain       prune + retrain with a PERP mode, report recovery
  reconstruct   prune + layer-wise reconstruction (Eq. 1)
  eval          evaluate the cached dense model (ppl + zero-shot)
  sweep         regenerate one paper table/figure (--exp <id>)
  tables        regenerate every table/figure

common flags:
  --model <name>       gpt-nano | gpt-tiny | gpt-small | llama-tiny  [gpt-tiny]
  --backend <b>        native | pjrt (pjrt needs the cargo feature)  [native]
  --profile <p>        quick | full                                 [quick]
  --artifacts <dir>    artifacts directory (pjrt backend only)       [./artifacts]
  --out <dir>          results + checkpoint cache                    [./results]
  --seed <n>           experiment seed                               [0]
  --criterion <c>      magnitude | magnitude-global | wanda | sparsegpt
  --sparsity <s>       0.5 | 50 | 2:4 | 4:8
  --mode <m>           full | biases | ln | biases_ln | head | embed |
                       lora | lora_prune | masklora | masklora_std | scalelora
  --steps <n>          override step counts
  --exp <id>           fig1 fig2 table1 table2 table3 table4 table5
                       table19 table20 table22 memory
";

struct Env {
    rt: Box<dyn Backend>,
    cfg: ExperimentConfig,
    out: PathBuf,
    seed: u64,
}

fn common(args: &Args) -> Result<Env> {
    let artifacts = args
        .opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let model = args.str("model", "gpt-tiny");
    let profile = args.str("profile", "quick");
    let mut cfg = ExperimentConfig::profile(&profile, &model)?;
    if let Some(cfg_file) = args.opt_str("config") {
        cfg = cfg.with_file(std::path::Path::new(&cfg_file))?;
    }
    if let Some(backend) = args.opt_str("backend") {
        cfg.backend = backend;
    }
    if let Some(steps) = args.opt_str("steps") {
        let steps: u64 = steps.parse().context("--steps")?;
        cfg.retrain_steps = steps;
    }
    if let Some(steps) = args.opt_str("pretrain-steps") {
        cfg.pretrain_steps = steps.parse().context("--pretrain-steps")?;
    }
    let kind = BackendKind::parse(&cfg.backend).map_err(|e| anyhow::anyhow!(e))?;
    let rt = open_backend(kind, &artifacts)?;
    let out = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out).ok();
    Ok(Env { rt, cfg, out, seed: args.u64("seed", 0) })
}

fn ctx(env: &Env) -> ExpContext<'_> {
    ExpContext::new(env.rt.as_ref(), env.cfg.clone(), env.out.join("cache"))
}

fn info(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "backend: {} (manifest: {:?})",
        env.rt.kind(),
        env.rt.manifest().dir
    );
    for (name, mm) in &env.rt.manifest().models {
        println!(
            "  {name}: {} params, {} executables, d={} L={} V={} bias={} norm={}",
            mm.total_params(),
            mm.executables.len(),
            mm.cfg.d_model,
            mm.cfg.n_layers,
            mm.cfg.vocab,
            mm.cfg.use_bias,
            mm.cfg.norm,
        );
        for mode in ["ln", "biases", "masklora", "full"] {
            let cnt = mm.trainable_count(mode);
            println!(
                "     trainable[{mode}]: {cnt} ({:.3}%)",
                100.0 * cnt as f64 / mm.total_params() as f64
            );
        }
    }
    for t in sweep::run(&ctx(&env), "memory")? {
        t.print();
    }
    Ok(())
}

fn pretrain(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let c = ctx(&env);
    let s = c.dense_session(env.seed)?;
    let ppl = s.eval_ppl_test()?;
    println!(
        "dense {}: test ppl {:.3} (loss {:.4}), last train tps {:.0}",
        env.cfg.model, ppl.ppl, ppl.loss, s.last_tps
    );
    Ok(())
}

fn parse_prune(args: &Args) -> Result<(Criterion, Pattern)> {
    let crit = Criterion::parse(&args.str("criterion", "magnitude"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let pattern = Pattern::parse(&args.str("sparsity", "0.5")).map_err(|e| anyhow::anyhow!(e))?;
    Ok((crit, pattern))
}

fn prune(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let c = ctx(&env);
    let (s, _) = c.pruned_session(env.seed, crit, pattern)?;
    let ppl = s.eval_ppl_test()?;
    println!(
        "{} @ {} ({}): achieved sparsity {:.3}, test ppl {:.2}",
        crit.name(),
        pattern.label(),
        env.cfg.model,
        s.masks.sparsity(),
        ppl.ppl
    );
    s.save(&env.out.join("pruned.ptns"))?;
    Ok(())
}

fn retrain(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    let mode = Mode::parse(&args.str("mode", "masklora")).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let c = ctx(&env);
    let (base, _) = c.pruned_session(env.seed, crit, pattern)?;
    let before = {
        let mut s = c.clone_session(&base)?;
        c.evaluate(&mut s, false, None)?
    };
    let (cell, lr) = c.retrain_tuned(&base, mode, env.cfg.retrain_steps, true)?;
    println!(
        "{} @ {} + {} ({} steps, lr {lr}): ppl {:.2} -> {:.2}, acc {:.1}%, tps {:.0}, trainable {:.3}%",
        crit.name(),
        pattern.label(),
        mode.name(),
        env.cfg.retrain_steps,
        before.ppl,
        cell.ppl,
        cell.acc * 100.0,
        cell.tps,
        cell.trainable_pct
    );
    Ok(())
}

fn reconstruct_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    let recon_mode = match args.str("recon-mode", "masklora").as_str() {
        "masklora" => ReconMode::MaskLora,
        "full" => ReconMode::FullFt,
        other => bail!("unknown recon mode {other:?}"),
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let c = ctx(&env);
    let (base, dense) = c.pruned_session(env.seed, crit, pattern)?;
    let before = {
        let mut s = c.clone_session(&base)?;
        c.evaluate(&mut s, false, None)?
    };
    let mut s = c.clone_session(&base)?;
    let target = s.masks.clone();
    let report = reconstruct::reconstruct(
        &mut s,
        &target,
        &dense,
        recon_mode,
        env.cfg.recon_steps,
        env.cfg.recon_lr,
    )?;
    let after = c.evaluate(&mut s, true, None)?;
    println!(
        "{} @ {} + reconstruction: ppl {:.2} -> {:.2}, acc {:.1}%, mean layer-loss drop {:.4}",
        crit.name(),
        pattern.label(),
        before.ppl,
        after.ppl,
        after.acc * 100.0,
        report.mean_improvement()
    );
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let c = ctx(&env);
    let s = c.dense_session(env.seed)?;
    let ppl = s.eval_ppl_test()?;
    let tasks = s.eval_tasks()?;
    println!("{}: test ppl {:.3}", env.cfg.model, ppl.ppl);
    for t in &tasks {
        println!("  {:>6}: {:.1}% ({} items)", t.name, t.accuracy * 100.0, t.items);
    }
    println!("  mean zero-shot acc: {:.1}%", perp::eval::mean_accuracy(&tasks) * 100.0);
    Ok(())
}

fn run_and_record(env: &Env, exp: &str) -> Result<()> {
    let c = ctx(env);
    let t0 = std::time::Instant::now();
    let tables = sweep::run(&c, exp)?;
    let path = env.out.join(format!("{exp}.md"));
    let _ = std::fs::remove_file(&path);
    for t in &tables {
        t.print();
        t.append_to(&path)?;
    }
    println!("[{exp}] done in {:.1}s -> {:?}", t0.elapsed().as_secs_f64(), path);
    Ok(())
}

fn sweep_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let exp = args.str("exp", "");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    if exp.is_empty() {
        bail!("--exp required; one of {:?}", sweep::EXPERIMENTS);
    }
    run_and_record(&env, &exp)
}

fn tables(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    for exp in sweep::EXPERIMENTS {
        run_and_record(&env, exp)?;
    }
    Ok(())
}
