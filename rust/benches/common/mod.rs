//! Shared bench scaffolding: every paper-table bench builds an ExpContext
//! against the cached quick-profile checkpoints and appends its markdown
//! table to `results/bench_tables.md`.
#![allow(dead_code)] // each bench target uses a subset of these helpers

use std::path::PathBuf;

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::{self, ExpContext};
use perp::runtime::{open_default_backend, Backend};

pub fn bench_model() -> String {
    std::env::var("PERP_BENCH_MODEL").unwrap_or_else(|_| "gpt-nano".to_string())
}

pub fn bench_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick(&bench_model());
    cfg.pretrain_steps = std::env::var("PERP_BENCH_PRETRAIN")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3000);
    cfg.retrain_steps = 60;
    cfg.recon_steps = 20;
    cfg.items_per_task = 20;
    cfg
}

pub fn run_experiment(exp: &str) {
    let rt = open_default_backend().expect("opening backend");
    let ctx = ExpContext::new(rt.as_ref(), bench_cfg(), PathBuf::from("results/cache"));
    let t0 = std::time::Instant::now();
    let tables = sweep::run(&ctx, exp).expect("sweep failed");
    let out = PathBuf::from("results/bench_tables.md");
    std::fs::create_dir_all("results").ok();
    for t in &tables {
        t.print();
        t.append_to(&out).ok();
    }
    println!(
        "bench[{exp}] ({}, {} backend): {:.1}s, {} executions",
        bench_model(),
        rt.kind(),
        t0.elapsed().as_secs_f64(),
        rt.exec_count()
    );
}
