//! Optimizer state management and learning-rate schedules.
//!
//! The AdamW *math* runs on-device (L1 `adamw.py` kernel inside every train
//! step); this module owns the state tensors between steps — which is the
//! paper's memory argument made concrete: [`OptState::bytes`] is exactly the
//! footprint that shrinks 10⁴× when retraining LN-params instead of
//! everything.

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// First/second-moment buffers for one trainable leaf set.
#[derive(Debug, Clone, Default)]
pub struct OptState {
    pub m: BTreeMap<String, Tensor>,
    pub v: BTreeMap<String, Tensor>,
    pub step: u64,
}

impl OptState {
    /// Zero state for the given (name, shape) leaves.
    pub fn zeros<'a>(leaves: impl Iterator<Item = (&'a str, &'a [usize])>) -> OptState {
        let mut m = BTreeMap::new();
        let mut v = BTreeMap::new();
        for (name, shape) in leaves {
            m.insert(name.to_string(), Tensor::zeros(shape));
            v.insert(name.to_string(), Tensor::zeros(shape));
        }
        OptState { m, v, step: 0 }
    }

    pub fn leaf_names(&self) -> impl Iterator<Item = &String> {
        self.m.keys()
    }

    /// Optimizer memory footprint in bytes (m + v, f32).
    pub fn bytes(&self) -> usize {
        2 * 4 * self.m.values().map(|t| t.numel()).sum::<usize>()
    }

    pub fn update(&mut self, name: &str, m: Tensor, v: Tensor) {
        assert!(self.m.contains_key(name), "unknown leaf {name:?}");
        self.m.insert(name.to_string(), m);
        self.v.insert(name.to_string(), v);
    }
}

/// Learning-rate schedules (paper: linear decay with 10% warmup for LLMs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    Constant { lr: f64 },
    /// linear warmup for `warmup` steps then linear decay to zero at `total`
    LinearWarmupDecay { peak: f64, warmup: u64, total: u64 },
}

impl Schedule {
    /// The paper's LLM default: 10% warmup, linear decay, tuned peak.
    pub fn paper_default(peak: f64, total_steps: u64) -> Schedule {
        Schedule::LinearWarmupDecay {
            peak,
            warmup: (total_steps / 10).max(1),
            total: total_steps.max(1),
        }
    }

    /// LR at 1-based step `t`.
    pub fn lr(&self, t: u64) -> f64 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::LinearWarmupDecay { peak, warmup, total } => {
                if t <= warmup {
                    peak * t as f64 / warmup as f64
                } else if t >= total {
                    0.0
                } else {
                    peak * (total - t) as f64 / (total - warmup) as f64
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_bytes() {
        let shapes: Vec<(String, Vec<usize>)> =
            vec![("a".into(), vec![2, 3]), ("b".into(), vec![10])];
        let st = OptState::zeros(shapes.iter().map(|(n, s)| (n.as_str(), s.as_slice())));
        assert_eq!(st.bytes(), 2 * 4 * 16);
        assert_eq!(st.leaf_names().count(), 2);
    }

    #[test]
    fn warmup_then_decay() {
        let s = Schedule::LinearWarmupDecay { peak: 1.0, warmup: 10, total: 110 };
        assert!((s.lr(1) - 0.1).abs() < 1e-12);
        assert!((s.lr(10) - 1.0).abs() < 1e-12);
        assert!(s.lr(60) < 1.0 && s.lr(60) > 0.0);
        assert_eq!(s.lr(110), 0.0);
        assert_eq!(s.lr(200), 0.0);
        // monotone decay after warmup
        assert!(s.lr(20) > s.lr(50));
    }

    #[test]
    fn paper_default_has_10pct_warmup() {
        let s = Schedule::paper_default(5e-4, 1000);
        match s {
            Schedule::LinearWarmupDecay { warmup, total, peak } => {
                assert_eq!(warmup, 100);
                assert_eq!(total, 1000);
                assert_eq!(peak, 5e-4);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn update_replaces_buffers() {
        let shapes: Vec<(String, Vec<usize>)> = vec![("a".into(), vec![2])];
        let mut st = OptState::zeros(shapes.iter().map(|(n, s)| (n.as_str(), s.as_slice())));
        st.update("a", Tensor::full(&[2], 1.0), Tensor::full(&[2], 2.0));
        assert_eq!(st.m["a"].data(), &[1.0, 1.0]);
        assert_eq!(st.v["a"].data(), &[2.0, 2.0]);
    }
}
