//! [`PlanGraph`]: fan-out pipeline plans as a DAG of named stage nodes.
//!
//! PERP's headline results are grids — sparsity × criterion × mode × seed
//! cells that share an expensive common prefix (pretrain → prune) and differ
//! only in a cheap suffix.  A linear [`Plan`] cannot express that sharing
//! *within one run*; a `PlanGraph` can: each node holds one [`Stage`] plus a
//! parent edge, so a prefix with several children executes once and forks
//! via a session snapshot.
//!
//! * **Nodes** are named (names appear in reports, `repro plan show`, and
//!   [`Aggregate`](NodeKind::Aggregate) references — never in cache keys).
//! * **Keys** are the root-path canonicalisation: a node's FNV-1a chain is
//!   `base_key(cfg, seed + seed_offset)` pushed with every stage from its
//!   root down to itself — exactly the linear-plan chain, so existing
//!   linear-plan cache entries stay valid and a linear [`Plan`] is just a
//!   single-path graph ([`Plan::to_graph`]).
//! * **Seed replication** clones a whole root path per seed offset
//!   (`replicate_seeds(n)`); replicas are bitwise-identical to running the
//!   same linear plan under `--seed base+i`.
//! * **Aggregate nodes** reduce a set of leaf `Eval` nodes into mean±std
//!   rows ([`crate::eval::mean_std`]); they execute after every stage node
//!   and never touch the cache.
//!
//! The [`GraphBuilder`] offers fluent fan-out combinators (`fork_over`,
//! `fork_sparsities`, `grid`, `replicate_seeds`, `aggregate`) over a
//! moving *frontier* of leaves; the low-level [`PlanGraph::stage_node`] /
//! [`PlanGraph::aggregate_node`] API is what the sweep generators use when
//! they need explicit cell names.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::reconstruct::ReconMode;
use crate::peft::Mode;
use crate::pruning::{Criterion, Pattern};
use crate::util::json::Json;

use super::cachekey::{base_key, Key};
use super::plan::{Plan, Stage};

/// What a graph node does.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// One pipeline stage, executed over the session inherited from
    /// `parent` (roots create the session — they must be `Pretrain`).
    Stage(Stage),
    /// Reduce the eval metrics of the named nodes into mean±std rows.
    Aggregate { over: Vec<String> },
}

/// One named node.  `parent` applies to stage nodes only (aggregates
/// reference their inputs through `over`); `seed_offset` shifts the
/// executor's base seed for seed-replicated paths.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub name: String,
    pub kind: NodeKind,
    pub parent: Option<String>,
    pub seed_offset: u64,
}

impl Node {
    /// Short human label (stage label, or `agg(n)` for aggregates).
    pub fn label(&self) -> String {
        match &self.kind {
            NodeKind::Stage(s) => s.label(),
            NodeKind::Aggregate { over } => format!("agg({})", over.len()),
        }
    }

    pub fn stage(&self) -> Option<&Stage> {
        match &self.kind {
            NodeKind::Stage(s) => Some(s),
            NodeKind::Aggregate { .. } => None,
        }
    }
}

/// A named DAG of stage nodes plus aggregate reducers.  Node order is
/// insertion order; the executor walks roots depth-first with children in
/// insertion order, so execution is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanGraph {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl PlanGraph {
    pub fn new(name: &str) -> PlanGraph {
        PlanGraph { name: name.to_string(), nodes: Vec::new() }
    }

    // ----- low-level construction (sweep generators) ----------------------

    /// Append a stage node.  `parent: None` declares a root (must be
    /// `Pretrain` — enforced by [`PlanGraph::validate`]).
    pub fn stage_node(&mut self, name: &str, parent: Option<&str>, stage: Stage) -> &mut Self {
        self.stage_node_at(name, parent, stage, self.seed_offset_of(parent))
    }

    /// [`PlanGraph::stage_node`] with an explicit seed offset (seed-replica
    /// paths).
    pub fn stage_node_at(
        &mut self,
        name: &str,
        parent: Option<&str>,
        stage: Stage,
        seed_offset: u64,
    ) -> &mut Self {
        self.nodes.push(Node {
            name: name.to_string(),
            kind: NodeKind::Stage(stage),
            parent: parent.map(str::to_string),
            seed_offset,
        });
        self
    }

    /// Append an aggregate node over the named eval nodes.
    pub fn aggregate_node(&mut self, name: &str, over: Vec<String>) -> &mut Self {
        self.nodes.push(Node {
            name: name.to_string(),
            kind: NodeKind::Aggregate { over },
            parent: None,
            seed_offset: 0,
        });
        self
    }

    fn seed_offset_of(&self, parent: Option<&str>) -> u64 {
        parent
            .and_then(|p| self.get(p))
            .map(|n| n.seed_offset)
            .unwrap_or(0)
    }

    // ----- lookups --------------------------------------------------------

    pub fn get(&self, name: &str) -> Option<&Node> {
        self.nodes.iter().find(|n| n.name == name)
    }

    /// Stage-node roots (parent = None), in insertion order.
    pub fn roots(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| n.parent.is_none() && n.stage().is_some())
            .collect()
    }

    /// Stage children of `name`, in insertion order.
    pub fn children(&self, name: &str) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| n.parent.as_deref() == Some(name) && n.stage().is_some())
            .collect()
    }

    /// Stage nodes with no stage children (the graph's leaves).
    pub fn leaves(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| n.stage().is_some() && self.children(&n.name).is_empty())
            .collect()
    }

    pub fn stage_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.stage().is_some()).count()
    }

    /// Root→node chain of node names (inclusive).  Errors on orphan parents
    /// and parent cycles — the primitive every validation walk reuses.
    pub fn path(&self, name: &str) -> Result<Vec<&Node>, String> {
        let mut chain = Vec::new();
        let mut cur = self
            .get(name)
            .ok_or_else(|| format!("unknown node {name:?}"))?;
        loop {
            chain.push(cur);
            if chain.len() > self.nodes.len() {
                return Err(format!("cycle in parent edges through node {name:?}"));
            }
            match &cur.parent {
                None => break,
                Some(p) => {
                    cur = self.get(p).ok_or_else(|| {
                        format!("node {:?} references unknown parent {p:?} (orphan)", cur.name)
                    })?;
                }
            }
        }
        chain.reverse();
        Ok(chain)
    }

    /// The stage labels along a node's root path — `pretrain → prune(...) →
    /// ...` — for human-facing rows.
    pub fn path_labels(&self, name: &str) -> Vec<String> {
        self.path(name)
            .map(|p| p.iter().map(|n| n.label()).collect())
            .unwrap_or_default()
    }

    /// Does any strict descendant of `name` hold a `Reconstruct` stage?
    /// (The executor snapshots reconstruction targets at prune nodes only
    /// when one does.)
    pub fn subtree_reconstructs(&self, name: &str) -> bool {
        self.children(name).iter().any(|c| {
            matches!(c.stage(), Some(Stage::Reconstruct { .. }))
                || self.subtree_reconstructs(&c.name)
        })
    }

    /// Content keys for every stage node: `base_key(cfg, seed+offset)`
    /// pushed with each stage canonical along the root path.  Single source
    /// of truth shared by the executor (artifact directories), `repro plan
    /// show` (cache-hit status) and `repro gc` (reachability).
    pub fn node_keys(
        &self,
        cfg: &ExperimentConfig,
        seed: u64,
    ) -> Result<BTreeMap<String, Key>, String> {
        let mut keys = BTreeMap::new();
        for node in &self.nodes {
            if node.stage().is_none() {
                continue;
            }
            let mut key = base_key(cfg, seed.wrapping_add(node.seed_offset));
            for step in self.path(&node.name)? {
                let stage = step
                    .stage()
                    .ok_or_else(|| format!("{:?} has an aggregate ancestor", node.name))?;
                key = key.push(&stage.canonical());
            }
            keys.insert(node.name.clone(), key);
        }
        Ok(keys)
    }

    // ----- validation -----------------------------------------------------

    /// Structural validation: duplicate names, orphan parents, parent
    /// cycles, non-`Pretrain` roots (and mid-path `Pretrain`s), seed-offset
    /// breaks along edges, aggregate references, and the linear stage-order
    /// rules of [`Plan::validate`] applied to every root→leaf path.
    pub fn validate(&self) -> Result<(), String> {
        if self.stage_count() == 0 {
            return Err("graph has no stage nodes".to_string());
        }
        let mut seen = BTreeSet::new();
        for n in &self.nodes {
            if !seen.insert(n.name.as_str()) {
                return Err(format!("duplicate node name {:?}", n.name));
            }
        }
        for n in &self.nodes {
            match &n.kind {
                NodeKind::Stage(stage) => {
                    // orphans + cycles surface through path()
                    self.path(&n.name)?;
                    if n.parent.is_none() && !matches!(stage, Stage::Pretrain) {
                        return Err(format!(
                            "root node {:?} must be a pretrain stage, got {}",
                            n.name,
                            stage.label()
                        ));
                    }
                    if let Some(p) = &n.parent {
                        let parent = self.get(p).expect("path() checked the parent");
                        if parent.stage().is_none() {
                            return Err(format!(
                                "node {:?} cannot descend from aggregate {p:?}",
                                n.name
                            ));
                        }
                        if parent.seed_offset != n.seed_offset {
                            return Err(format!(
                                "node {:?} changes seed offset mid-path ({} -> {}); replicas \
                                 must clone their whole root path",
                                n.name, parent.seed_offset, n.seed_offset
                            ));
                        }
                    }
                }
                NodeKind::Aggregate { over } => {
                    if over.is_empty() {
                        return Err(format!("aggregate {:?} reduces nothing", n.name));
                    }
                    for target in over {
                        match self.get(target) {
                            None => {
                                return Err(format!(
                                    "aggregate {:?} references unknown node {target:?}",
                                    n.name
                                ))
                            }
                            Some(t) if !matches!(t.stage(), Some(Stage::Eval { .. })) => {
                                return Err(format!(
                                    "aggregate {:?} must reduce eval nodes, {target:?} is {}",
                                    n.name,
                                    t.label()
                                ))
                            }
                            Some(_) => {}
                        }
                    }
                }
            }
        }
        // every root→leaf path must be a valid linear plan
        for leaf in self.leaves() {
            let stages: Vec<Stage> = self
                .path(&leaf.name)?
                .iter()
                .filter_map(|n| n.stage().cloned())
                .collect();
            Plan { name: format!("{}:{}", self.name, leaf.name), stages }
                .validate()
                .map_err(|e| format!("path to {:?}: {e}", leaf.name))?;
        }
        Ok(())
    }

    // ----- (de)serialization ----------------------------------------------

    pub fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                let mut pairs = vec![("name", Json::Str(n.name.clone()))];
                match &n.kind {
                    NodeKind::Stage(s) => {
                        if let Some(p) = &n.parent {
                            pairs.push(("parent", Json::Str(p.clone())));
                        }
                        if n.seed_offset != 0 {
                            pairs.push(("seed_offset", Json::Num(n.seed_offset as f64)));
                        }
                        pairs.push(("stage", s.to_json()));
                    }
                    NodeKind::Aggregate { over } => {
                        pairs.push((
                            "aggregate",
                            Json::Arr(over.iter().map(|s| Json::Str(s.clone())).collect()),
                        ));
                    }
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    pub fn to_string_pretty(&self) -> String {
        // one node per line keeps graph files diffable, like Plan files
        let mut out = String::new();
        out.push_str(&format!("{{\"name\":{},\n \"nodes\":[\n", Json::Str(self.name.clone())));
        let j = self.to_json();
        let arr = j.get("nodes").and_then(Json::as_arr).expect("just built");
        for (i, nj) in arr.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&nj.to_string());
            if i + 1 < arr.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    pub fn from_json(j: &Json) -> Result<PlanGraph, String> {
        let name = j.str_or("name", "graph");
        let nodes = j
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| "plan graph needs a \"nodes\" array".to_string())?;
        let mut g = PlanGraph::new(&name);
        for nj in nodes {
            let nname = nj
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("graph node missing \"name\": {nj}"))?
                .to_string();
            if let Some(over) = nj.get("aggregate") {
                let over = over
                    .as_arr()
                    .ok_or_else(|| format!("node {nname:?}: \"aggregate\" must be an array"))?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| format!("node {nname:?}: aggregate entries are names"))
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                g.aggregate_node(&nname, over);
            } else {
                let stage = Stage::from_json(
                    nj.get("stage")
                        .ok_or_else(|| format!("node {nname:?} needs \"stage\" or \"aggregate\""))?,
                )?;
                let parent = nj.get("parent").and_then(Json::as_str).map(str::to_string);
                let seed_offset = match nj.get("seed_offset") {
                    None => 0,
                    Some(v) => {
                        let f = v
                            .as_f64()
                            .filter(|f| *f >= 0.0 && f.fract() == 0.0)
                            .ok_or_else(|| {
                                format!("node {nname:?}: bad \"seed_offset\" {v}")
                            })?;
                        f as u64
                    }
                };
                g.stage_node_at(&nname, parent.as_deref(), stage, seed_offset);
            }
        }
        Ok(g)
    }

    pub fn from_text(s: &str) -> Result<PlanGraph, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        PlanGraph::from_json(&j)
    }

    pub fn from_file(path: &Path) -> Result<PlanGraph> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading graph {path:?}"))?;
        PlanGraph::from_text(&text).map_err(|e| anyhow::anyhow!("parsing graph {path:?}: {e}"))
    }

    // ----- rendering ------------------------------------------------------

    /// ASCII tree of the stage forest plus aggregate rows; `annotate`
    /// supplies a per-node suffix (`repro plan show` injects cache status).
    pub fn render_tree(&self, annotate: &dyn Fn(&Node) -> String) -> String {
        let mut out = String::new();
        let roots = self.roots();
        for (i, root) in roots.iter().enumerate() {
            self.render_subtree(root, "", i + 1 == roots.len(), annotate, &mut out);
        }
        for n in self.nodes.iter().filter(|n| n.stage().is_none()) {
            if let NodeKind::Aggregate { over } = &n.kind {
                out.push_str(&format!("◇ {}  over {} {}\n", n.name, over.len(), annotate(n)));
            }
        }
        out
    }

    fn render_subtree(
        &self,
        node: &Node,
        prefix: &str,
        last: bool,
        annotate: &dyn Fn(&Node) -> String,
        out: &mut String,
    ) {
        let tee = if last { "└─ " } else { "├─ " };
        out.push_str(&format!(
            "{prefix}{tee}{} [{}] {}\n",
            node.name,
            node.label(),
            annotate(node)
        ));
        let child_prefix = format!("{prefix}{}", if last { "   " } else { "│  " });
        let kids = self.children(&node.name);
        for (i, kid) in kids.iter().enumerate() {
            self.render_subtree(kid, &child_prefix, i + 1 == kids.len(), annotate, out);
        }
    }

    /// Graphviz DOT of the full graph (aggregate edges dashed).
    pub fn render_dot(&self, annotate: &dyn Fn(&Node) -> String) -> String {
        let quote = |s: &str| format!("\"{}\"", s.replace('"', "\\\""));
        let mut out = format!(
            "digraph {} {{\n  rankdir=TB;\n  node [shape=box];\n",
            quote(&self.name)
        );
        for n in &self.nodes {
            let note = annotate(n);
            let label = if note.is_empty() {
                format!("{}\\n{}", n.name, n.label())
            } else {
                format!("{}\\n{} {}", n.name, n.label(), note)
            };
            let shape = if n.stage().is_none() { ", shape=diamond" } else { "" };
            out.push_str(&format!("  {} [label={}{shape}];\n", quote(&n.name), quote(&label)));
        }
        for n in &self.nodes {
            match &n.kind {
                NodeKind::Stage(_) => {
                    if let Some(p) = &n.parent {
                        out.push_str(&format!("  {} -> {};\n", quote(p), quote(&n.name)));
                    }
                }
                NodeKind::Aggregate { over } => {
                    for target in over {
                        out.push_str(&format!(
                            "  {} -> {} [style=dashed];\n",
                            quote(target),
                            quote(&n.name)
                        ));
                    }
                }
            }
        }
        out.push_str("}\n");
        out
    }
}

/// A plan or a plan graph, as loaded from disk — `repro run --plan` accepts
/// both (`"stages"` ⇒ linear, `"nodes"` ⇒ graph).
#[derive(Debug, Clone, PartialEq)]
pub enum PlanOrGraph {
    Linear(Plan),
    Graph(PlanGraph),
}

impl PlanOrGraph {
    pub fn from_file(path: &Path) -> Result<PlanOrGraph> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading plan {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing plan {path:?}: {e}"))?;
        if j.get("nodes").is_some() {
            PlanGraph::from_json(&j)
                .map(PlanOrGraph::Graph)
                .map_err(|e| anyhow::anyhow!("parsing graph {path:?}: {e}"))
        } else {
            Plan::from_json(&j)
                .map(PlanOrGraph::Linear)
                .map_err(|e| anyhow::anyhow!("parsing plan {path:?}: {e}"))
        }
    }

    /// The graph to execute or key, whichever form was loaded.
    pub fn graph(&self) -> PlanGraph {
        match self {
            PlanOrGraph::Linear(p) => p.to_graph(),
            PlanOrGraph::Graph(g) => g.clone(),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            PlanOrGraph::Linear(p) => &p.name,
            PlanOrGraph::Graph(g) => &g.name,
        }
    }
}

// ---------------------------------------------------------------------------
// Fluent builder.
// ---------------------------------------------------------------------------

/// Builds a [`PlanGraph`] by extending a *frontier* of current leaves: each
/// combinator attaches to every frontier node, so a `stage` after a fork
/// extends all branches, and nested forks form grids.
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    g: PlanGraph,
    frontier: Vec<String>,
    counter: usize,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder { g: PlanGraph::new(name), frontier: Vec::new(), counter: 0 }
    }

    /// Deterministic auto-name: `n<counter>-<label>` (names never feed cache
    /// keys, but determinism keeps parsed specs round-trippable).
    fn auto_name(&mut self, label: &str) -> String {
        self.counter += 1;
        let slug: String = label
            .chars()
            .map(|c| if c.is_alphanumeric() || c == ':' || c == '.' || c == '%' { c } else { '-' })
            .collect();
        format!("n{}-{slug}", self.counter)
    }

    /// Current frontier leaves (the parse layer recurses through forks).
    pub fn frontier(&self) -> Vec<String> {
        self.frontier.clone()
    }

    pub fn set_frontier(&mut self, frontier: Vec<String>) {
        self.frontier = frontier;
    }

    /// Append `stage` to every frontier leaf (or as the root when the graph
    /// is empty).  Returns the new frontier implicitly.
    pub fn stage(mut self, stage: Stage) -> GraphBuilder {
        self.push_stage(&stage);
        self
    }

    fn push_stage(&mut self, stage: &Stage) {
        let parents: Vec<Option<String>> = if self.frontier.is_empty() {
            vec![None]
        } else {
            self.frontier.iter().cloned().map(Some).collect()
        };
        let mut next = Vec::with_capacity(parents.len());
        for parent in parents {
            let name = self.auto_name(&stage.label());
            self.g.stage_node(&name, parent.as_deref(), stage.clone());
            next.push(name);
        }
        self.frontier = next;
    }

    // Plan-builder mirrors, so linear chains read the same in both APIs.

    pub fn pretrain(self) -> GraphBuilder {
        self.stage(Stage::Pretrain)
    }
    pub fn prune(self, criterion: Criterion, pattern: Pattern) -> GraphBuilder {
        self.stage(Stage::Prune { criterion, pattern })
    }
    pub fn retrain(self, mode: Mode, steps: Option<u64>, lr: Option<f64>) -> GraphBuilder {
        self.stage(Stage::Retrain { mode, steps, lr })
    }
    pub fn reconstruct(self, mode: ReconMode, steps: Option<u64>, lr: Option<f64>) -> GraphBuilder {
        self.stage(Stage::Reconstruct { mode, steps, lr })
    }
    pub fn merge(self) -> GraphBuilder {
        self.stage(Stage::Merge)
    }
    pub fn eval(self) -> GraphBuilder {
        self.stage(Stage::Eval { tasks: true })
    }
    pub fn eval_ppl(self) -> GraphBuilder {
        self.stage(Stage::Eval { tasks: false })
    }
    pub fn export(self, path: &str) -> GraphBuilder {
        self.stage(Stage::Export { path: path.to_string() })
    }

    /// Fan out: attach each branch (a chain of stages) to every frontier
    /// leaf; the new frontier is every branch's last node.
    pub fn fork(mut self, branches: Vec<Vec<Stage>>) -> GraphBuilder {
        assert!(!branches.is_empty(), "fork needs at least one branch");
        let base = self.frontier.clone();
        let mut next = Vec::new();
        for branch in &branches {
            assert!(!branch.is_empty(), "fork branches cannot be empty");
            self.frontier = base.clone();
            for stage in branch {
                self.push_stage(stage);
            }
            next.extend(self.frontier.drain(..));
        }
        self.frontier = next;
        self
    }

    /// Fan out over single stages: one branch per stage.
    pub fn fork_over(self, stages: Vec<Stage>) -> GraphBuilder {
        self.fork(stages.into_iter().map(|s| vec![s]).collect())
    }

    /// Fan out over unstructured sparsities with one prune criterion — the
    /// PERP sweep staple (`fork_over(sparsities)` in the paper's shape).
    pub fn fork_sparsities(self, criterion: Criterion, sparsities: &[f64]) -> GraphBuilder {
        self.fork_over(
            sparsities
                .iter()
                .map(|&f| Stage::Prune { criterion, pattern: Pattern::Unstructured(f) })
                .collect(),
        )
    }

    /// The criterion × mode grid: for each criterion a shared prune node,
    /// under it one retrain branch per mode (+ a merge for the merging LoRA
    /// variants).  Frontier becomes every cell's last node.
    pub fn grid(mut self, criteria: &[(Criterion, Pattern)], modes: &[Mode]) -> GraphBuilder {
        assert!(!criteria.is_empty() && !modes.is_empty(), "grid needs both axes");
        let base = self.frontier.clone();
        let mut next = Vec::new();
        for &(criterion, pattern) in criteria {
            self.frontier = base.clone();
            self.push_stage(&Stage::Prune { criterion, pattern });
            let pruned = self.frontier.clone();
            for &mode in modes {
                self.frontier = pruned.clone();
                self.push_stage(&Stage::Retrain { mode, steps: None, lr: None });
                if mode.is_lora() && mode != Mode::Lora {
                    self.push_stage(&Stage::Merge);
                }
                next.extend(self.frontier.drain(..));
            }
        }
        self.frontier = next;
        self
    }

    /// Replicate every frontier leaf's whole root path once per extra seed
    /// offset `1..n` (offset 0 keeps the original path).  Replica nodes are
    /// suffixed `@s<i>`; shared prefixes are deduplicated, so two leaves
    /// over one prefix still share their replicated prefix per seed.
    pub fn replicate_seeds(self, n: u64) -> GraphBuilder {
        self.try_replicate_seeds(n).expect("replicate_seeds")
    }

    /// Fallible [`GraphBuilder::replicate_seeds`] (the `--stages` parser
    /// reports instead of panicking).
    pub fn try_replicate_seeds(mut self, n: u64) -> Result<GraphBuilder, String> {
        if n == 0 {
            return Err("seeds(n) needs n >= 1".to_string());
        }
        let mut next = self.frontier.clone();
        for leaf in self.frontier.clone() {
            let chain: Vec<(String, Stage, u64)> = self
                .g
                .path(&leaf)?
                .iter()
                .map(|node| {
                    (
                        node.name.clone(),
                        node.stage().cloned().expect("stage path"),
                        node.seed_offset,
                    )
                })
                .collect();
            if chain.iter().any(|(_, _, off)| *off != 0) {
                return Err("nested seeds(n) replication is not supported".to_string());
            }
            for i in 1..n {
                let mut parent: Option<String> = None;
                for (orig, stage, _) in &chain {
                    let clone_name = format!("{orig}@s{i}");
                    if self.g.get(&clone_name).is_none() {
                        self.g
                            .stage_node_at(&clone_name, parent.as_deref(), stage.clone(), i);
                    }
                    parent = Some(clone_name);
                }
                next.push(parent.expect("non-empty path"));
            }
        }
        self.frontier = next;
        Ok(self)
    }

    /// Aggregate the current frontier (which must be eval leaves) into one
    /// mean±std row.  The frontier is left untouched — aggregates are
    /// terminal reducers, not pipeline stages.
    pub fn aggregate(mut self, name: &str) -> GraphBuilder {
        let over = self.frontier.clone();
        self.g.aggregate_node(name, over);
        self
    }

    pub fn build(self) -> PlanGraph {
        self.g
    }
}

impl Plan {
    /// A linear plan *is* a single-path graph: chain the stages under
    /// auto-names.  Keys are unchanged — they never depend on node names.
    pub fn to_graph(&self) -> PlanGraph {
        let mut g = PlanGraph::new(&self.name);
        let mut parent: Option<String> = None;
        for (i, stage) in self.stages.iter().enumerate() {
            let name = format!("s{}", i + 1);
            g.stage_node(&name, parent.as_deref(), stage.clone());
            parent = Some(name);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fan() -> PlanGraph {
        GraphBuilder::new("fan")
            .pretrain()
            .fork_sparsities(Criterion::Magnitude, &[0.5, 0.7, 0.9])
            .eval_ppl()
            .aggregate("mean")
            .build()
    }

    #[test]
    fn builder_fans_out_and_shares_the_root() {
        let g = fan();
        g.validate().unwrap();
        assert_eq!(g.roots().len(), 1);
        assert_eq!(g.stage_count(), 1 + 3 + 3);
        assert_eq!(g.leaves().len(), 3);
        let agg = g.get("mean").unwrap();
        assert_eq!(
            agg.kind,
            NodeKind::Aggregate {
                over: g.leaves().iter().map(|n| n.name.clone()).collect()
            }
        );
        // all prunes hang off the single pretrain root
        let root = g.roots()[0].name.clone();
        assert_eq!(g.children(&root).len(), 3);
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let g = fan();
        let g2 = PlanGraph::from_text(&g.to_json().to_string()).unwrap();
        assert_eq!(g, g2);
        let g3 = PlanGraph::from_text(&g.to_string_pretty()).unwrap();
        assert_eq!(g, g3);
    }

    #[test]
    fn seed_replication_clones_whole_paths() {
        let g = GraphBuilder::new("seeds")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .eval_ppl()
            .replicate_seeds(3)
            .aggregate("mean")
            .build();
        g.validate().unwrap();
        // 3 seeds × (pretrain + prune + eval)
        assert_eq!(g.stage_count(), 9);
        assert_eq!(g.roots().len(), 3);
        let offsets: BTreeSet<u64> = g.roots().iter().map(|r| r.seed_offset).collect();
        assert_eq!(offsets, BTreeSet::from([0, 1, 2]));
        // replicas keep the linear chain keys of their own seed
        let cfg = ExperimentConfig::quick("gpt-nano");
        let keys = g.node_keys(&cfg, 0).unwrap();
        let linear = Plan::new("lin")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .eval_ppl();
        for (leaf, seed) in g.leaves().iter().zip([0u64, 1, 2]) {
            let mut k = base_key(&cfg, seed);
            for s in &linear.stages {
                k = k.push(&s.canonical());
            }
            assert_eq!(keys[&leaf.name], k, "leaf {} seed {seed}", leaf.name);
        }
    }

    #[test]
    fn linear_plan_keys_survive_graph_conversion() {
        let plan = Plan::new("lin")
            .pretrain()
            .prune(Criterion::Wanda, Pattern::Unstructured(0.5))
            .retrain(Mode::MaskLora, Some(10), None)
            .merge()
            .eval();
        let g = plan.to_graph();
        g.validate().unwrap();
        let cfg = ExperimentConfig::quick("gpt-nano");
        let keys = g.node_keys(&cfg, 7).unwrap();
        let mut k = base_key(&cfg, 7);
        for (i, s) in plan.stages.iter().enumerate() {
            k = k.push(&s.canonical());
            assert_eq!(keys[&format!("s{}", i + 1)], k, "stage {i}");
        }
    }

    #[test]
    fn validation_rejects_bad_graphs() {
        // cycle (hand-built: a → b → a)
        let mut g = PlanGraph::new("cycle");
        g.stage_node("a", Some("b"), Stage::Pretrain);
        g.stage_node("b", Some("a"), Stage::Merge);
        assert!(g.validate().unwrap_err().contains("cycle"));

        // orphan parent
        let mut g = PlanGraph::new("orphan");
        g.stage_node("root", None, Stage::Pretrain);
        g.stage_node("child", Some("ghost"), Stage::Eval { tasks: false });
        assert!(g.validate().unwrap_err().contains("orphan"));

        // duplicate name
        let mut g = PlanGraph::new("dup");
        g.stage_node("x", None, Stage::Pretrain);
        g.stage_node("x", None, Stage::Pretrain);
        assert!(g.validate().unwrap_err().contains("duplicate"));

        // root must be pretrain
        let mut g = PlanGraph::new("root");
        g.stage_node(
            "p",
            None,
            Stage::Prune { criterion: Criterion::Magnitude, pattern: Pattern::Unstructured(0.5) },
        );
        assert!(g.validate().unwrap_err().contains("pretrain"));

        // mid-path pretrain (linear rules per path)
        let mut g = PlanGraph::new("mid");
        g.stage_node("a", None, Stage::Pretrain);
        g.stage_node("b", Some("a"), Stage::Pretrain);
        assert!(g.validate().unwrap_err().contains("first"));

        // aggregate over a non-eval node
        let mut g = PlanGraph::new("agg");
        g.stage_node("a", None, Stage::Pretrain);
        g.aggregate_node("m", vec!["a".into()]);
        assert!(g.validate().unwrap_err().contains("eval"));

        // aggregate over a missing node
        let mut g = PlanGraph::new("agg2");
        g.stage_node("a", None, Stage::Pretrain);
        g.aggregate_node("m", vec!["nope".into()]);
        assert!(g.validate().unwrap_err().contains("unknown"));

        // seed offset breaks mid-path
        let mut g = PlanGraph::new("seed");
        g.stage_node_at("a", None, Stage::Pretrain, 0);
        g.stage_node_at("b", Some("a"), Stage::Eval { tasks: false }, 1);
        assert!(g.validate().unwrap_err().contains("seed offset"));
    }

    #[test]
    fn grid_shares_prunes_across_modes() {
        let g = GraphBuilder::new("grid")
            .pretrain()
            .grid(
                &[
                    (Criterion::Magnitude, Pattern::Unstructured(0.5)),
                    (Criterion::Wanda, Pattern::Unstructured(0.5)),
                ],
                &[Mode::Biases, Mode::MaskLora],
            )
            .eval_ppl()
            .build();
        g.validate().unwrap();
        // 1 pretrain + 2 prunes + 2×(biases retrain) + 2×(masklora retrain+merge) + 4 evals
        assert_eq!(g.stage_count(), 1 + 2 + 2 + 4 + 4);
        let root = g.roots()[0].name.clone();
        assert_eq!(g.children(&root).len(), 2, "one prune per criterion");
        for prune in g.children(&root) {
            assert_eq!(g.children(&prune.name).len(), 2, "one retrain per mode");
        }
    }
}
