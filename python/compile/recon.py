"""L2: layer-wise reconstruction graphs (PERP §3.3, Eq. 1).

For a linear with original weights W0, mask M and calibration inputs X, the
reconstruction problem is

    min_{Ŵ} ‖ W0 X − (M ⊙ Ŵ) X ‖²   .

Two parametrisations, per the paper:

* **MaskLoRA** (memory-efficient): Ŵ = W + s·B@A with only (A, B) trained —
  the optimizer state is ~0.35% of the layer.
* **Full-FT** (Table 19 baseline): Ŵ = W trained directly with masked grads —
  the paper shows this *overfits the calibration set* at high sparsity.

Both steps take the precomputed dense targets Y0 = X @ W0^T (produced once by
the ``linear_fwd`` executable) so the frozen GEMM is not re-run every
iteration.  One executable per distinct (out, in) shape is AOT-compiled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import adamw_update, masked_lora_matmul, masked_matmul, mm_nt


def linear_fwd(x, w):
    """Y0 = X @ W^T — the dense reconstruction target."""
    return mm_nt(x, w)


def recon_loss_masklora(x, y0, w, mask, a, b, scale):
    """Mean-squared reconstruction error of the MaskLoRA-reparametrised layer.

    Scaled by out-dim so magnitudes match the Frobenius form of Eq. 1 per row.
    """
    y = masked_lora_matmul(x, w, mask, a, b, scale)
    return jnp.mean(jnp.square(y - y0)) * y.shape[-1]


def recon_loss_full(x, y0, w, mask):
    y = masked_matmul(x, w, mask)
    return jnp.mean(jnp.square(y - y0)) * y.shape[-1]


def make_recon_step_masklora(scale: float):
    """step(x, y0, w, mask, a, b, ma, va, mb, vb, step_i, lr)
    -> (a', b', ma', va', mb', vb', loss)."""

    def step(x, y0, w, mask, a, b, ma, va, mb, vb, step_i, lr):
        def loss_fn(ab):
            return recon_loss_masklora(x, y0, w, mask, ab[0], ab[1], scale)

        loss, (ga, gb) = jax.value_and_grad(loss_fn)((a, b))
        a2, ma2, va2 = adamw_update(a, ga, ma, va, step_i, lr)
        b2, mb2, vb2 = adamw_update(b, gb, mb, vb, step_i, lr)
        return a2, b2, ma2, va2, mb2, vb2, loss

    return step


def make_recon_step_full():
    """step(x, y0, w, mask, mw, vw, step_i, lr) -> (w', mw', vw', loss).

    Gradients are masked automatically through masked_matmul's VJP, so pruned
    entries stay exactly zero during optimisation (footnote 1 of the paper).
    """

    def step(x, y0, w, mask, mw, vw, step_i, lr):
        def loss_fn(w_):
            return recon_loss_full(x, y0, w_, mask)

        loss, gw = jax.value_and_grad(loss_fn)(w)
        w2, mw2, vw2 = adamw_update(w, gw, mw, vw, step_i, lr)
        return w2, mw2, vw2, loss

    return step
