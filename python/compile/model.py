"""L2: the GPT model family, loss, scoring and per-PEFT-mode train steps.

Build-time only — this module is lowered once by aot.py into HLO-text
artifacts; Python never runs on the request path.  The rust coordinator owns
parameters/optimizer state between step calls and feeds them back in.

Architecture: pre-LN GPT (OPT-style) with learned positional embeddings,
GELU MLP, biases on every linear, untied head — or the LLaMA-style variant
(RMSNorm, no biases) via ``use_bias=False, norm="rmsnorm"``.  The distinction
is load-bearing in the paper: its "Biases" retraining subset does not exist
for LLaMA-2 (Table 8).

Pruning scope follows Sun et al. (2023)/PERP exactly: all linear layers of
every transformer block (q, k, v, o, fc, proj) are maskable; embeddings and
the final head are never pruned.

All dense/sparse/LoRA contractions route through the L1 Pallas kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import (
    adamw_update,
    attention,
    layernorm,
    masked_lora_matmul,
    masked_matmul,
    dmm_nt,
    rmsnorm,
    scale_lora_matmul,
)

# ---------------------------------------------------------------------------
# Configs.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + retraining hyperparameters for one model."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    use_bias: bool = True          # OPT-style; False => LLaMA-style
    norm: str = "layernorm"        # "layernorm" | "rmsnorm"
    lora_rank: int = 16
    lora_alpha: float = 32.0
    train_batch: int = 8           # static batch of the train-step artifacts
    eval_batch: int = 8            # static batch of eval/score artifacts
    calib_rows: int = 512          # rows per layer-wise reconstruction chunk

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / self.lora_rank


# The repro fleet.  The paper's 1.3B -> 30B axis maps onto tiny -> medium:
# what is checked is *relative* behaviour (collapse, recovery, trainable-%).
CONFIGS = {
    "gpt-nano": ModelConfig("gpt-nano", vocab=128, d_model=32, n_layers=2,
                            n_heads=2, seq_len=32, lora_rank=4,
                            train_batch=4, eval_batch=4, calib_rows=128),
    "gpt-tiny": ModelConfig("gpt-tiny", vocab=256, d_model=64, n_layers=2,
                            n_heads=2, seq_len=64, lora_rank=8,
                            train_batch=8, eval_batch=8, calib_rows=256),
    "gpt-small": ModelConfig("gpt-small", vocab=512, d_model=128, n_layers=4,
                             n_heads=4, seq_len=128, lora_rank=16),
    "gpt-medium": ModelConfig("gpt-medium", vocab=1024, d_model=256,
                              n_layers=6, n_heads=8, seq_len=128, lora_rank=16),
    "llama-tiny": ModelConfig("llama-tiny", vocab=512, d_model=128, n_layers=4,
                              n_heads=4, seq_len=128, use_bias=False,
                              norm="rmsnorm", lora_rank=16),
    # end-to-end example scale (examples/prune_retrain_e2e.rs)
    "gpt-e2e": ModelConfig("gpt-e2e", vocab=2048, d_model=384, n_layers=6,
                           n_heads=8, seq_len=128, lora_rank=16,
                           train_batch=8, eval_batch=8),
}


# ---------------------------------------------------------------------------
# Parameter specs: the single source of truth for names, shapes and ordering.
# The rust ParamStore mirrors this list (via the manifest) byte-for-byte.
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...], str]]:
    """(name, shape, group) for every parameter, in canonical order.

    Groups: embed | ln | bias | weight | head — PERP's retraining subsets.
    """
    specs: list[tuple[str, tuple[int, ...], str]] = [
        ("embed_tokens", (cfg.vocab, cfg.d_model), "embed"),
        ("embed_pos", (cfg.seq_len, cfg.d_model), "embed"),
    ]
    d, ff = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layers):
        p = f"h{i}_"
        specs.append((p + "ln1_scale", (d,), "ln"))
        if cfg.norm == "layernorm":
            specs.append((p + "ln1_bias", (d,), "ln"))
        for lin in ("attn_q", "attn_k", "attn_v", "attn_o"):
            specs.append((p + lin + "_w", (d, d), "weight"))
            if cfg.use_bias:
                specs.append((p + lin + "_b", (d,), "bias"))
        specs.append((p + "ln2_scale", (d,), "ln"))
        if cfg.norm == "layernorm":
            specs.append((p + "ln2_bias", (d,), "ln"))
        specs.append((p + "mlp_fc_w", (ff, d), "weight"))
        if cfg.use_bias:
            specs.append((p + "mlp_fc_b", (ff,), "bias"))
        specs.append((p + "mlp_proj_w", (d, ff), "weight"))
        if cfg.use_bias:
            specs.append((p + "mlp_proj_b", (d,), "bias"))
    specs.append(("final_ln_scale", (d,), "ln"))
    if cfg.norm == "layernorm":
        specs.append(("final_ln_bias", (d,), "ln"))
    specs.append(("head_w", (cfg.vocab, cfg.d_model), "head"))
    return specs


def tap_names(cfg: ModelConfig) -> list[str]:
    """Distinct capture points, in forward order.  q/k/v consume the same
    activation, so one tap (named after attn_q) covers all three."""
    out = []
    for i in range(cfg.n_layers):
        p = f"h{i}_"
        out += [p + "attn_q_w", p + "attn_o_w", p + "mlp_fc_w", p + "mlp_proj_w"]
    return out


def tap_of(name: str) -> str:
    """Map a prunable linear to the tap that carries its input."""
    return name.replace("attn_k", "attn_q").replace("attn_v", "attn_q")


def prunable_names(cfg: ModelConfig) -> list[str]:
    """The maskable linears, in canonical order (matches mask ordering)."""
    return [n for n, _, g in param_specs(cfg) if g == "weight"]


def param_order(cfg: ModelConfig) -> list[str]:
    return [n for n, _, _ in param_specs(cfg)]


def adapter_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """LoRA adapter tensors, one (A, B) pair per prunable linear.

    A: (r, in) — named ``<linear>::A``;  B: (out, r) — named ``<linear>::B``.
    """
    shapes = dict((n, s) for n, s, _ in param_specs(cfg))
    out = []
    for n in prunable_names(cfg):
        o, i = shapes[n]
        out.append((n + "::A", (cfg.lora_rank, i)))
        out.append((n + "::B", (o, cfg.lora_rank)))
    return out


# Trainable-subset predicates, keyed by retraining mode (PERP §3.1/§3.2).
# LoRA modes additionally train biases + LN (paper: "further also retrain
# biases and LN-parameters").
SUBSET_MODES = {
    "full": lambda g: True,
    "biases": lambda g: g == "bias",
    "ln": lambda g: g == "ln",
    "biases_ln": lambda g: g in ("bias", "ln"),
    "head": lambda g: g == "head",
    "embed": lambda g: g == "embed",
}
LORA_MODES = ("lora", "masklora", "masklora_std", "scalelora")
ALL_MODES = tuple(SUBSET_MODES) + LORA_MODES


def trainable_names(cfg: ModelConfig, mode: str) -> list[str]:
    """Model parameters (not adapters) trained under ``mode``."""
    if mode in SUBSET_MODES:
        pred = SUBSET_MODES[mode]
        return [n for n, _, g in param_specs(cfg) if pred(g)]
    if mode in LORA_MODES:
        return [n for n, _, g in param_specs(cfg) if g in ("bias", "ln")]
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _norm(cfg: ModelConfig, params, prefix: str, x2d):
    if cfg.norm == "layernorm":
        return layernorm(x2d, params[prefix + "_scale"], params[prefix + "_bias"])
    return rmsnorm(x2d, params[prefix + "_scale"])


def _linear(cfg: ModelConfig, params, masks, adapters, mode, name, x2d):
    """Dispatch a (possibly pruned / adapted) linear by retraining mode.

    x2d: (N, in) — callers flatten (B, S) first.  Weight (out, in).
    """
    w = params[name + "_w"]
    m = masks[name + "_w"]
    if mode in SUBSET_MODES or adapters is None:
        y = masked_matmul(x2d, w, m)
    elif mode == "lora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        # classic LoRA keeps W frozen-sparse and adds the (unmasked) low-rank
        # path, exploiting associativity: (x A^T) B^T — BA never materialised.
        y = masked_matmul(x2d, w, m) + cfg.lora_scale * dmm_nt(dmm_nt(x2d, a), b)
    elif mode == "masklora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        y = masked_lora_matmul(x2d, w, m, a, b, cfg.lora_scale)
    elif mode == "masklora_std":
        # the paper's *unoptimized* MaskLoRA: materialise BA at (out, in),
        # mask it, add to W, then a plain GEMM.  Kept as the Table 4
        # "MaskLoRA (standard)" throughput baseline.
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        z = w * m + m * (cfg.lora_scale * (b @ a))
        y = dmm_nt(x2d, z)
    elif mode == "scalelora":
        a, b = adapters[name + "_w::A"], adapters[name + "_w::B"]
        y = scale_lora_matmul(x2d, w, m, a, b)
    else:
        raise ValueError(mode)
    if cfg.use_bias:
        y = y + params[name + "_b"][None, :]
    return y


def forward(cfg: ModelConfig, params, masks, tokens, adapters=None,
            mode: str = "full", capture: list | None = None):
    """Token ids (B, S) -> logits (B, S, V).

    ``capture``, when a list, receives (linear_name, x2d) pairs for every
    prunable linear — the tap used by the calibration/reconstruction path.
    """
    bsz, s = tokens.shape
    d = cfg.d_model
    x = params["embed_tokens"][tokens] + params["embed_pos"][None, :s, :]

    def tap(name, x2d):
        if capture is not None:
            capture.append((name + "_w", x2d))

    for i in range(cfg.n_layers):
        p = f"h{i}_"
        h = _norm(cfg, params, p + "ln1", x.reshape(bsz * s, d))
        tap(p + "attn_q", h)
        q = _linear(cfg, params, masks, adapters, mode, p + "attn_q", h)
        k = _linear(cfg, params, masks, adapters, mode, p + "attn_k", h)
        v = _linear(cfg, params, masks, adapters, mode, p + "attn_v", h)

        def heads(t):
            return t.reshape(bsz, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

        o = attention(heads(q), heads(k), heads(v), True)
        o = o.transpose(0, 2, 1, 3).reshape(bsz * s, d)
        tap(p + "attn_o", o)
        o = _linear(cfg, params, masks, adapters, mode, p + "attn_o", o)
        x = x + o.reshape(bsz, s, d)

        h = _norm(cfg, params, p + "ln2", x.reshape(bsz * s, d))
        tap(p + "mlp_fc", h)
        f = _linear(cfg, params, masks, adapters, mode, p + "mlp_fc", h)
        f = jax.nn.gelu(f)
        tap(p + "mlp_proj", f)
        f = _linear(cfg, params, masks, adapters, mode, p + "mlp_proj", f)
        x = x + f.reshape(bsz, s, d)

    h = _norm(cfg, params, "final_ln", x.reshape(bsz * s, d))
    logits = dmm_nt(h, params["head_w"])  # head never pruned
    return logits.reshape(bsz, s, cfg.vocab)


# ---------------------------------------------------------------------------
# Losses / scoring.
# ---------------------------------------------------------------------------


def lm_loss_sums(logits, tokens):
    """Next-token CE.  Returns (loss_sum, token_count) so the caller can
    aggregate exact perplexity across batches."""
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), jnp.float32(tgt.size)


def lm_loss_mean(logits, tokens):
    s, c = lm_loss_sums(logits, tokens)
    return s / c


def sequence_scores(logits, tokens, tmask):
    """Per-sequence sum log-prob of the tokens where tmask==1 (EleutherAI-
    style likelihood ranking).  tmask marks *target* positions; the token at
    position t is scored with the logits at t-1."""
    lp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    tm = tmask[:, 1:]
    tok_lp = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(tok_lp * tm, axis=1), jnp.sum(tm, axis=1)


# ---------------------------------------------------------------------------
# Train steps (one jitted function per retraining mode).
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mode: str) -> Callable:
    """Returns step(trainable, frozen, masks, adapters, m, v, tokens, step_i, lr)
    -> (new_trainable_and_adapters, new_m, new_v, loss).

    ``trainable``/``adapters`` are dicts; AdamW state dicts ``m, v`` are keyed
    identically to the trainables.  Frozen params receive no gradient and no
    optimizer state — that asymmetry IS the paper's memory argument.
    """
    assert mode in ALL_MODES, mode
    is_lora = mode in LORA_MODES

    def step(trainable, frozen, masks, adapters, m, v, tokens, step_i, lr):
        def loss_fn(train_leaves):
            params = dict(frozen)
            ad = None
            if is_lora:
                ad = {k: train_leaves[k] for k in adapters}
            for k in trainable:
                params[k] = train_leaves[k]
            logits = forward(cfg, params, masks, tokens, adapters=ad, mode=mode)
            return lm_loss_mean(logits, tokens)

        leaves = dict(trainable)
        if is_lora:
            leaves.update(adapters)
        loss, grads = jax.value_and_grad(loss_fn)(leaves)
        new_leaves, new_m, new_v = {}, {}, {}
        for k, p in leaves.items():
            new_leaves[k], new_m[k], new_v[k] = adamw_update(
                p, grads[k], m[k], v[k], step_i, lr
            )
        return new_leaves, new_m, new_v, loss

    return step


# ---------------------------------------------------------------------------
# Calibration statistics (feeds rust-side Wanda + SparseGPT).
# ---------------------------------------------------------------------------


def calib_stats(cfg: ModelConfig, params, masks, tokens):
    """Per-prunable-linear Gram matrices G = X^T X over this batch.

    Wanda consumes sqrt(diag(G)); SparseGPT consumes the full G (Hessian
    H = 2 G + λI up to scaling).  Accumulation across batches happens in rust.
    """
    capture: list = []
    forward(cfg, params, masks, tokens, mode="full", capture=capture)
    return [(name, x.T @ x) for name, x in capture]


def capture_layer_inputs(cfg: ModelConfig, params, masks, tokens):
    """The raw inputs X (N, in) of every prunable linear for this batch —
    consumed by the layer-wise reconstruction scheduler."""
    capture: list = []
    forward(cfg, params, masks, tokens, mode="full", capture=capture)
    return capture
