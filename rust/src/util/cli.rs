//! Tiny CLI argument parser (clap replacement).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`.
//! Typed accessors with defaults; unknown-argument detection via
//! [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut subcommand = None;
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(key.to_string());
                }
            } else if subcommand.is_none() {
                subcommand = Some(a.clone());
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
            i += 1;
        }
        Ok(Args { subcommand, opts, flags, consumed: Default::default() })
    }

    pub fn from_env() -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    pub fn opt_usize(&self, key: &str) -> Option<usize> {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option.
    pub fn list(&self, key: &str, default: &str) -> Vec<String> {
        self.str(key, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// Error on any option/flag that no accessor ever looked at.
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(format!("unknown argument --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = args("sweep --exp table1 --seed 3 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.str("exp", ""), "table1");
        assert_eq!(a.u64("seed", 0), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = args("run --lr=0.001 --steps=100");
        assert_eq!(a.f64("lr", 0.0), 0.001);
        assert_eq!(a.usize("steps", 0), 100);
    }

    #[test]
    fn list_option() {
        let a = args("x --models a,b,,c");
        assert_eq!(a.list("models", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn defaults() {
        let a = args("x");
        assert_eq!(a.str("missing", "d"), "d");
        assert_eq!(a.usize("n", 7), 7);
    }

    #[test]
    fn opt_usize_present_and_absent() {
        let a = args("serve --port 7070");
        assert_eq!(a.opt_usize("port"), Some(7070));
        assert_eq!(a.opt_usize("threads"), None);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_args_detected() {
        let a = args("x --known 1 --unknown 2");
        let _ = a.usize("known", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn rejects_extra_positional() {
        let v: Vec<String> = ["a", "b"].iter().map(|s| s.to_string()).collect();
        assert!(Args::parse(&v).is_err());
    }
}
