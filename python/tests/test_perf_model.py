"""Structural perf invariants of the L1 kernels (DESIGN.md §Perf):
every BlockSpec the kernels would choose — from repro scale up to the
paper's OPT-30B layer shapes — must fit VMEM and keep MXU-aligned tiles.
"""

from hypothesis import given, settings, strategies as st

from compile.perf_model import (
    VMEM_BYTES,
    masked_lora_estimate,
    paper_scale_rows,
)
from compile.kernels.common import MatmulBlocks, pick_block


def test_paper_scale_tiles_fit_vmem():
    for e in paper_scale_rows():
        assert e.vmem_bytes <= VMEM_BYTES, (e.shape, e.vmem_bytes)


def test_large_shapes_are_compute_bound():
    # the OPT-scale masked-lora tiles must land compute-bound, matching the
    # paper's observation that MaskLoRA (optimized) approaches LoRA speed
    for e in paper_scale_rows():
        out_dim = int(e.shape.split("(")[2].split("x")[0])
        if out_dim >= 2560:
            assert e.roofline_bound == "compute", e.shape


@settings(max_examples=50, deadline=None)
@given(
    n=st.integers(8, 8192),
    m=st.integers(8, 8192),
    k=st.integers(8, 8192),
    r=st.sampled_from([4, 8, 16, 32]),
)
def test_any_shape_fits_vmem(n, m, k, r):
    e = masked_lora_estimate(n, m, k, r)
    assert e.vmem_bytes <= VMEM_BYTES


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 4096), preferred=st.sampled_from([128, 256]))
def test_pick_block_divides(dim, preferred):
    b = pick_block(dim, preferred)
    assert 1 <= b <= max(dim, preferred)
    if dim % preferred == 0:
        assert b == preferred
    else:
        assert dim % b == 0 or b == preferred


def test_blocks_choose_mxu_tiles_when_possible():
    blk = MatmulBlocks.choose(4096, 2560, 2560)
    assert blk.bn == 128 and blk.bm == 128 and blk.bk == 256
