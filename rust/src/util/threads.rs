//! Kernel thread-pool sizing and the shared thread budget.
//!
//! The rayon global pool defaults to one thread per logical core — correct
//! for batch experiments, but the serving layer also runs HTTP workers and
//! per-model engine threads on the same host, and oversubscription turns
//! into tail latency.  `--threads <n>` (or `PERP_THREADS=<n>`) pins the
//! kernel pool size explicitly; call [`configure`] before the first rayon
//! use (the CLI does this while parsing common flags).
//!
//! The parallel plan-graph scheduler adds a second axis: `--jobs {auto,K}`
//! (or `PERP_JOBS`) runs up to K graph nodes concurrently.  Left alone, N
//! concurrent nodes would each fan their kernels over the whole global
//! pool — N×budget threads on budget cores.  Instead every in-flight node
//! [`acquire_share`]s a slice of the budget: with N nodes live it gets
//! `max(1, budget / N)` threads as a scoped rayon pool its kernels run
//! inside, and as nodes retire, later acquisitions see a smaller N and get
//! proportionally more.  A node that is alone (or a serial run) skips the
//! scoped pool entirely and uses the global one — zero overhead.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Size the global rayon pool: explicit argument wins, then
/// `PERP_THREADS`, otherwise rayon's default.  Returns the effective
/// thread count.  A second call (or a call after rayon was already used)
/// cannot resize the pool — it warns and reports the existing size.
pub fn configure(threads: Option<usize>) -> usize {
    let requested = threads.or_else(from_env);
    if let Some(n) = requested {
        let n = n.max(1);
        match rayon::ThreadPoolBuilder::new().num_threads(n).build_global() {
            Ok(()) => crate::debug!("rayon pool sized to {n} threads"),
            Err(e) => {
                if rayon::current_num_threads() != n {
                    crate::warn!(
                        "rayon pool already initialised with {} threads ({e}); \
                         --threads/PERP_THREADS ignored",
                        rayon::current_num_threads()
                    );
                }
            }
        }
    }
    rayon::current_num_threads()
}

/// Parse `PERP_THREADS` (ignored when unset, empty or non-numeric).
pub fn from_env() -> Option<usize> {
    std::env::var("PERP_THREADS").ok().and_then(|v| v.trim().parse().ok())
}

/// Total kernel-thread budget: the global rayon pool size (after
/// [`configure`], that is `--threads`/`PERP_THREADS` or all cores).
pub fn budget() -> usize {
    rayon::current_num_threads().max(1)
}

/// `--jobs {auto,K}` — how many plan-graph nodes may execute concurrently.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Jobs {
    /// Size the worker count to the kernel thread budget.
    Auto,
    /// Exactly K concurrent nodes (K ≥ 1; 1 = the serial DFS walk).
    Fixed(usize),
}

impl Jobs {
    /// Resolve to a concrete worker count.  `auto` means one worker per
    /// budget thread: each in-flight node then runs its kernels on ~1
    /// thread, which maximises cross-node concurrency for the
    /// embarrassingly-parallel sweep grids.
    pub fn resolve(self) -> usize {
        match self {
            Jobs::Auto => budget(),
            Jobs::Fixed(n) => n.max(1),
        }
    }
}

impl std::str::FromStr for Jobs {
    type Err = ();

    fn from_str(s: &str) -> Result<Jobs, ()> {
        if s.trim() == "auto" {
            return Ok(Jobs::Auto);
        }
        match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Jobs::Fixed(n)),
            _ => Err(()),
        }
    }
}

/// Parse `PERP_JOBS` (`auto` or a positive integer; ignored when unset,
/// empty or malformed).
pub fn jobs_from_env() -> Option<Jobs> {
    std::env::var("PERP_JOBS").ok().and_then(|v| v.parse().ok())
}

/// Graph nodes currently holding a budget share.
static IN_FLIGHT: AtomicUsize = AtomicUsize::new(0);

/// RAII slice of the kernel-thread budget held by one in-flight graph
/// node.  Dropping it returns the slice to the pool of later acquirers.
pub struct BudgetShare {
    threads: usize,
    /// scoped pool the node's kernels run inside; `None` = global pool
    pool: Option<rayon::ThreadPool>,
    /// covers the share's hold window so budget rebalancing shows up as a
    /// timeline when tracing is on
    _span: crate::obs::trace::Span,
}

/// Claim a slice of the kernel budget for one node.  With N nodes live
/// the slice is `max(1, budget / N)` threads; a node that is alone keeps
/// the whole budget on the global pool (no scoped pool is built).
pub fn acquire_share() -> BudgetShare {
    let live = IN_FLIGHT.fetch_add(1, Ordering::SeqCst) + 1;
    let total = budget();
    let slice = (total / live).max(1);
    let pool = if slice < total {
        rayon::ThreadPoolBuilder::new().num_threads(slice).build().ok()
    } else {
        None
    };
    let threads = if pool.is_some() { slice } else { total };
    let span = crate::span!("threads", "budget.share")
        .arg("threads", threads)
        .arg("live", live);
    BudgetShare { threads, pool, _span: span }
}

impl BudgetShare {
    /// Kernel threads this share runs on.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` with this share installed: rayon `par_*` calls inside use
    /// the share's scoped pool (or the global pool for a whole-budget
    /// share).
    pub fn run<R: Send>(&self, f: impl FnOnce() -> R + Send) -> R {
        match &self.pool {
            Some(pool) => pool.install(f),
            None => f(),
        }
    }
}

impl Drop for BudgetShare {
    fn drop(&mut self) {
        IN_FLIGHT.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configure_reports_a_live_pool() {
        // No explicit request: must not panic, and the pool has ≥ 1 thread.
        assert!(configure(None) >= 1);
        // A redundant explicit request after initialisation stays sane.
        let n = rayon::current_num_threads();
        assert_eq!(configure(Some(n)), n);
    }

    #[test]
    fn jobs_parse_and_resolve() {
        assert_eq!("auto".parse::<Jobs>(), Ok(Jobs::Auto));
        assert_eq!("4".parse::<Jobs>(), Ok(Jobs::Fixed(4)));
        assert!("0".parse::<Jobs>().is_err());
        assert!("-2".parse::<Jobs>().is_err());
        assert!("many".parse::<Jobs>().is_err());
        assert!(Jobs::Auto.resolve() >= 1);
        assert_eq!(Jobs::Fixed(3).resolve(), 3);
    }

    #[test]
    fn budget_shares_split_and_rebalance() {
        let total = budget();
        // a lone node keeps the whole budget (global pool, no scoped pool)
        let a = acquire_share();
        assert_eq!(a.threads(), total);
        assert_eq!(a.run(|| 40 + 2), 42);
        // a second concurrent node gets at most half, never zero
        let b = acquire_share();
        assert!(b.threads() >= 1);
        assert!(b.threads() <= (total / 2).max(1));
        assert_eq!(b.run(|| rayon::current_num_threads()), b.threads());
        drop(b);
        drop(a);
        // after everyone retires, a fresh share sees the full budget again
        let c = acquire_share();
        assert_eq!(c.threads(), total);
    }
}
