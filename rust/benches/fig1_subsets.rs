//! `cargo bench --bench fig1_subsets` — regenerates the paper's fig1
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("fig1");
}
