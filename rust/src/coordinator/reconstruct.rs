//! Sequential layer-wise reconstruction (PERP §3.3, Eq. 1).
//!
//! For each transformer block, in order:
//!
//! 1. capture the inputs X of every linear in the block by running the
//!    network with *already-reconstructed* earlier blocks and *original
//!    dense* later blocks (the SparseGPT sequential convention);
//! 2. per linear: targets Y0 = X @ W0ᵀ from the dense weights, then
//!    AdamW on the MaskLoRA-reparametrised (or full-FT) reconstruction
//!    objective, cycling fixed-size calibration chunks;
//! 3. merge and write back; the block's masks switch from dense to pruned.
//!
//! Memory note (the paper's §3.3 argument): only one block's activations and
//! one layer's adapter state are ever alive — `metrics::training_memory`
//! quantifies the reduction.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::optim::OptState;
use crate::pruning::MaskSet;
use crate::runtime::{Backend, Feed};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::session::Session;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReconMode {
    MaskLora,
    FullFt,
}

#[derive(Debug, Clone)]
pub struct ReconReport {
    /// (linear, first-step loss, last-step loss)
    pub layers: Vec<(String, f32, f32)>,
}

impl ReconReport {
    pub fn mean_improvement(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers
            .iter()
            .map(|(_, first, last)| (*first as f64 - *last as f64).max(0.0))
            .sum::<f64>()
            / self.layers.len() as f64
    }
}

/// Run layer-wise reconstruction toward `target_masks`.
///
/// Preconditions: `session.params` holds the *dense* weights (SparseGPT
/// callers pass its updated weights as `w_start` overrides), masks are reset
/// dense by this function before the sweep.
pub fn reconstruct(
    session: &mut Session,
    target_masks: &MaskSet,
    dense_params: &BTreeMap<String, Tensor>,
    mode: ReconMode,
    iters: u64,
    lr: f64,
) -> Result<ReconReport> {
    // Reconstruction *starts from* the pruned session's current weights —
    // for SparseGPT that means its OBS-updated weights, for magnitude/Wanda
    // the masked originals — while the *targets* Y0 always come from the
    // dense weights (Eq. 1's W_l).
    let start_params: BTreeMap<String, Tensor> = session
        .mm
        .prunable
        .iter()
        .map(|n| (n.clone(), session.params.get(n).clone()))
        .collect();
    let mm = session.mm.clone();
    let cfg_rows = mm.cfg.calib_rows;
    let rank = mm.cfg.lora_rank;
    let scale = mm.cfg.lora_scale as f32;
    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let shape = [b, s];
    let model = mm.cfg.name.clone();

    // the capture prefix uses reconstructed blocks; unvisited blocks run
    // dense (the SparseGPT sequential convention)
    // restore dense weights *before* reset_masks so its sparse rebuild —
    // kept in lockstep with the per-block mutations below — runs on the
    // dense state once instead of compressing the stale pruned weights
    for n in &mm.prunable {
        session.params.set(n, dense_params[n].clone());
    }
    session.reset_masks();

    let calib = session
        .train
        .calibration(session.cfg.calib_seqs, b, session.cfg.data_seed);

    let mut report = ReconReport { layers: Vec::new() };
    let mut rng = Rng::new(session.cfg.data_seed ^ 0x5EC0);

    for block in 0..mm.cfg.n_layers {
        let block_prefix = format!("h{block}_");
        let block_linears: Vec<String> = mm
            .prunable
            .iter()
            .filter(|n| n.starts_with(&block_prefix))
            .cloned()
            .collect();

        // ---- capture X for this block over all calibration batches -----
        let mut xrows: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        for tokens in &calib {
            // capture runs the pruned forward — CSR-routed layers apply here
            let feed = session.feed().ints("tokens", &shape, tokens);
            let out = session.rt.run(&model, "capture_inputs", &feed)?;
            for (name, t) in out.values {
                let key = name.strip_prefix("x::").unwrap_or(&name).to_string();
                if key.starts_with(&block_prefix) {
                    xrows.entry(key).or_default().extend_from_slice(t.data());
                }
            }
        }

        // ---- per-linear optimisation ------------------------------------
        for lin in &block_linears {
            let w0 = dense_params
                .get(lin)
                .with_context(|| format!("dense weights missing {lin}"))?;
            let wstart = &start_params[lin];
            let (out_dim, in_dim) = (w0.shape()[0], w0.shape()[1]);
            let tag = format!("{out_dim}x{in_dim}");
            let mask = target_masks.get(lin).clone();

            // calibration chunks of exactly calib_rows rows (q/k/v share a tap)
            let tap = mm.taps.get(lin).unwrap_or(lin);
            let all = xrows.get(tap).context("no captured rows")?.clone();
            let total_rows = all.len() / in_dim;
            let n_chunks = (total_rows / cfg_rows).max(1);
            let chunk = |i: usize| -> Tensor {
                let start = (i % n_chunks) * cfg_rows * in_dim;
                let end = (start + cfg_rows * in_dim).min(all.len());
                let mut data = all[start..end].to_vec();
                data.resize(cfg_rows * in_dim, 0.0);
                Tensor::new(&[cfg_rows, in_dim], data)
            };

            // targets per chunk (cached) through the linear_fwd executable
            let mut y0_cache: Vec<Option<Tensor>> = vec![None; n_chunks];
            let mut y0 = |session: &Session, i: usize, x: &Tensor| -> Result<Tensor> {
                if let Some(t) = &y0_cache[i % n_chunks] {
                    return Ok(t.clone());
                }
                let feed = Feed::new().tensor("x", x).tensor("w", w0);
                let mut out = session.rt.run(&model, &format!("linear_fwd_{tag}"), &feed)?;
                let t = out.take("y0");
                y0_cache[i % n_chunks] = Some(t.clone());
                Ok(t)
            };

            let (mut first_loss, mut last_loss) = (f32::NAN, f32::NAN);
            match mode {
                ReconMode::MaskLora => {
                    let mut a = Tensor::randn(&[rank, in_dim], 0.02, &mut rng);
                    let mut bmat = Tensor::zeros(&[out_dim, rank]);
                    let mut opt = OptState::zeros(
                        [
                            ("a", &[rank, in_dim][..]),
                            ("b", &[out_dim, rank][..]),
                        ]
                        .into_iter(),
                    );
                    for t in 1..=iters {
                        let x = chunk(t as usize - 1);
                        let y = y0(session, t as usize - 1, &x)?;
                        let feed = Feed::new()
                            .tensor("x", &x)
                            .tensor("y0", &y)
                            .tensor("w", wstart)
                            .tensor("mask", &mask)
                            .tensor("a", &a)
                            .tensor("b", &bmat)
                            .tensor("om::a", &opt.m["a"])
                            .tensor("ov::a", &opt.v["a"])
                            .tensor("om::b", &opt.m["b"])
                            .tensor("ov::b", &opt.v["b"])
                            .scalar("step", t as f32)
                            .scalar("lr", lr as f32);
                        let mut out =
                            session.rt.run(&model, &format!("recon_masklora_{tag}"), &feed)?;
                        let loss = out.scalar("loss");
                        if t == 1 {
                            first_loss = loss;
                        }
                        last_loss = loss;
                        a = out.take("o::a");
                        bmat = out.take("o::b");
                        opt.update("a", out.take("om::a"), out.take("ov::a"));
                        opt.update("b", out.take("om::b"), out.take("ov::b"));
                    }
                    let merged = crate::peft::merge::masklora(wstart, &mask, &a, &bmat, scale);
                    debug_assert!(crate::peft::merge::preserves_sparsity(&merged, &mask));
                    session.params.set(lin, merged);
                }
                ReconMode::FullFt => {
                    let mut w = wstart.hadamard(&mask);
                    let mut opt = OptState::zeros(
                        [("w", &[out_dim, in_dim][..])].into_iter(),
                    );
                    for t in 1..=iters {
                        let x = chunk(t as usize - 1);
                        let y = y0(session, t as usize - 1, &x)?;
                        let feed = Feed::new()
                            .tensor("x", &x)
                            .tensor("y0", &y)
                            .tensor("w", &w)
                            .tensor("mask", &mask)
                            .tensor("om::w", &opt.m["w"])
                            .tensor("ov::w", &opt.v["w"])
                            .scalar("step", t as f32)
                            .scalar("lr", lr as f32);
                        let mut out =
                            session.rt.run(&model, &format!("recon_full_{tag}"), &feed)?;
                        let loss = out.scalar("loss");
                        if t == 1 {
                            first_loss = loss;
                        }
                        last_loss = loss;
                        w = out.take("o::w");
                        opt.update("w", out.take("om::w"), out.take("ov::w"));
                    }
                    session.params.set(lin, w.hadamard(&mask));
                }
            }
            session.masks.set(lin, mask);
            report.layers.push((lin.clone(), first_loss, last_loss));
        }
        // this block now runs pruned in later blocks' captures; only its
        // own linears changed, so skip the full-model rescan
        session.refresh_sparse_layers(&block_linears);
    }
    // force exact zeros everywhere
    session.params.apply_masks(&session.masks.masks);
    session.refresh_sparse();
    Ok(report)
}
