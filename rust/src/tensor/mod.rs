//! Host tensor library.
//!
//! Model weights, masks, adapters and optimizer state live on the host
//! between PJRT executions; the pruning criteria (magnitude / Wanda /
//! SparseGPT) run entirely on these tensors.  f32, row-major, contiguous.
//!
//! Submodules: [`linalg`] (blocked matmul, Cholesky toolchain for
//! SparseGPT's OBS solver), [`sparse`] (CSR weight layout + SpMM kernels),
//! [`io`] (checkpoint serialization), [`pool`] (thread-local buffer reuse
//! for the native backend's per-step tapes).

pub mod io;
pub mod linalg;
pub mod pool;
pub mod sparse;

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ----- constructors ---------------------------------------------------
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
    }

    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    // ----- metadata ---------------------------------------------------------
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-matrix {:?}", self.shape);
        self.shape[0]
    }
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-matrix {:?}", self.shape);
        self.shape[1]
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    // ----- element access ---------------------------------------------------
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }
    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    // ----- shape ops ----------------------------------------------------------
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    // ----- elementwise ----------------------------------------------------------
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "shape mismatch in zip");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn hadamard(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn abs(&self) -> Tensor {
        self.map(f32::abs)
    }

    // ----- reductions ----------------------------------------------------------
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }
    pub fn mean(&self) -> f64 {
        self.sum() / self.numel() as f64
    }
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
    pub fn count(&self, pred: impl Fn(f32) -> bool) -> usize {
        self.data.iter().filter(|&&x| pred(x)).count()
    }

    /// Fraction of exactly-zero entries (the sparsity invariant checks).
    pub fn zero_fraction(&self) -> f64 {
        self.count(|x| x == 0.0) as f64 / self.numel() as f64
    }

    /// Symmetric closeness check: |a - b| <= atol + rtol * max(|a|, |b|).
    ///
    /// The relative term uses the larger magnitude of the pair so the check
    /// is order-independent (allclose(a, b) == allclose(b, a)), and the
    /// caller controls the relative tolerance explicitly.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(&a, &b)| (a - b).abs() <= atol + rtol * a.abs().max(b.abs()))
    }

    // ----- matmul (delegates to linalg) -------------------------------------
    /// self:(n,k) @ other:(k,m) -> (n,m)
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        linalg::matmul(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(t.transpose2().transpose2(), t);
        assert_eq!(t.transpose2().at2(3, 2), t.at2(2, 3));
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![10., 20., 30., 40.]);
        assert_eq!(a.add(&b).data(), &[11., 22., 33., 44.]);
        assert_eq!(a.hadamard(&b).data(), &[10., 40., 90., 160.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4., 6., 8.]);
        assert_eq!(b.sub(&a).data(), &[9., 18., 27., 36.]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(&[4], vec![1., -2., 0., 3.]);
        assert_eq!(t.sum(), 2.0);
        assert_eq!(t.max_abs(), 3.0);
        assert_eq!(t.zero_fraction(), 0.25);
        assert_eq!(t.sq_norm(), 14.0);
    }

    #[test]
    fn allclose_is_symmetric_and_tolerant() {
        let a = Tensor::new(&[2], vec![100.0, 0.0]);
        let b = Tensor::new(&[2], vec![100.001, 1e-7]);
        // pure-atol check fails, rtol on max(|a|,|b|) passes either way round
        assert!(!a.allclose(&b, 1e-6, 0.0));
        assert!(a.allclose(&b, 1e-6, 1e-4));
        assert!(b.allclose(&a, 1e-6, 1e-4));
        // shape mismatch is never close
        assert!(!a.allclose(&Tensor::zeros(&[3]), 1e9, 1.0));
    }

    #[test]
    fn eye_and_scalar() {
        let i = Tensor::eye(3);
        assert_eq!(i.at2(1, 1), 1.0);
        assert_eq!(i.at2(0, 1), 0.0);
        assert_eq!(Tensor::scalar(5.0).numel(), 1);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(2);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean = t.mean();
        let var = t.sq_norm() / t.numel() as f64 - mean * mean;
        assert!(mean.abs() < 0.02);
        assert!((var - 0.25).abs() < 0.02);
    }
}
