//! Quickstart: the PERP story in one minute on gpt-nano.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```
//!
//! 1. pretrain (or load the cached) dense model;
//! 2. magnitude-prune 50% → perplexity degrades;
//! 3. retrain ONLY the biases (≈1% of params at this scale, 0.03% at OPT
//!    scale) → most of the damage is gone;
//! 4. retrain with MaskLoRA and merge losslessly → sparsity preserved.

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::ExpContext;
use perp::peft::Mode;
use perp::pruning::{Criterion, Pattern};
use perp::runtime::open_default_backend;

fn main() -> Result<()> {
    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::quick("gpt-nano");
    cfg.pretrain_steps = 3000;
    cfg.retrain_steps = 150;
    let ctx = ExpContext::new(rt.as_ref(), cfg, "results/cache".into());

    println!("== 1. dense model ==");
    let dense = ctx.dense_session(0)?;
    let dense_ppl = dense.eval_ppl_test()?;
    println!("dense test perplexity: {:.2}", dense_ppl.ppl);

    println!("\n== 2. magnitude pruning @ 50% ==");
    let (pruned, _) = ctx.pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(0.5))?;
    let pruned_ppl = pruned.eval_ppl_test()?;
    println!(
        "pruned perplexity: {:.2}  (x{:.2} vs dense) — sparsity {:.1}%",
        pruned_ppl.ppl,
        pruned_ppl.ppl / dense_ppl.ppl,
        100.0 * pruned.masks.sparsity()
    );

    println!("\n== 3. retrain ONLY the biases ==");
    let (bias_cell, lr) = ctx.retrain_tuned(&pruned, Mode::Biases, 150, false)?;
    println!(
        "biases retrained (lr {lr}): perplexity {:.2} — trainable {:.3}% of params",
        bias_cell.ppl, bias_cell.trainable_pct
    );

    println!("\n== 4. MaskLoRA: mergeable, sparsity-preserving ==");
    let mut s = ctx.clone_session(&pruned)?;
    s.retrain(Mode::MaskLora, 150, lr)?;
    s.merge_adapters()?; // panics if any pruned weight were resurrected
    let ml = s.eval_ppl_test()?;
    println!(
        "masklora retrained+merged: perplexity {:.2}; post-merge sparsity {:.1}%",
        ml.ppl,
        100.0 * s.params.weight_sparsity(&s.mm)
    );

    println!(
        "\nsummary: dense {:.2} | pruned {:.2} | +biases {:.2} | +masklora {:.2}",
        dense_ppl.ppl, pruned_ppl.ppl, bias_cell.ppl, ml.ppl
    );
    Ok(())
}
