//! Micro-benchmarks of the execution layer: the rayon-parallel matmul
//! kernels against their single-thread baselines (the NativeBackend hot
//! path), plus prepare/steady-state latency of the backend graphs.
//!
//! The matmul table is the acceptance gauge for the parallel kernel work —
//! on ≥4 cores the rayon column should be ≥2× the serial column at the
//! GEMM sizes the retraining loop actually runs.

mod common;

use perp::config::ExperimentConfig;
use perp::coordinator::Session;
use perp::eval::base_feed;
use perp::optim::OptState;
use perp::runtime::{open_default_backend, Backend};
use perp::tensor::{linalg, pool, sparse, Tensor};
use perp::util::bench::{fmt_duration, Bench, Table};
use perp::util::rng::Rng;

fn matmul_speedups(out: &mut Vec<Table>) {
    let bench = Bench::quick();
    let mut t = Table::new(
        &format!(
            "matmul kernels: serial vs rayon ({} cores)",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        ),
        &["op", "shape", "serial", "rayon", "speedup"],
    );
    let mut rng = Rng::new(42);
    for (n, k, m) in [(256usize, 256usize, 256usize), (512, 512, 512), (1024, 256, 1024)] {
        let a = Tensor::randn(&[n, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, m], 1.0, &mut rng);
        let s = bench.run(|| {
            std::hint::black_box(linalg::matmul_serial(&a, &b));
        });
        let p = bench.run(|| {
            std::hint::black_box(linalg::matmul(&a, &b));
        });
        t.row(vec![
            "matmul".into(),
            format!("{n}x{k} @ {k}x{m}"),
            fmt_duration(s.mean),
            fmt_duration(p.mean),
            format!("{:.2}x", s.mean_secs() / p.mean_secs()),
        ]);
        let bt = Tensor::randn(&[m, k], 1.0, &mut rng);
        let s = bench.run(|| {
            std::hint::black_box(linalg::matmul_nt_serial(&a, &bt));
        });
        let p = bench.run(|| {
            std::hint::black_box(linalg::matmul_nt(&a, &bt));
        });
        t.row(vec![
            "matmul_nt".into(),
            format!("{n}x{k} @ ({m}x{k})T"),
            fmt_duration(s.mean),
            fmt_duration(p.mean),
            format!("{:.2}x", s.mean_secs() / p.mean_secs()),
        ]);
    }
    t.print();
    out.push(t);
}

/// A/B: the old masked-forward path (materialise W⊙M, then `matmul_nt`)
/// against the fused `matmul_nt_masked` and the compressed CSR `spmm_nt`
/// (only surviving weights loaded).  The full sparsity ladder with
/// machine-readable output lives in `repro bench-kernels`.
fn masked_matmul_ab(out: &mut Vec<Table>) {
    let bench = Bench::quick();
    let mut t = Table::new(
        "masked forward: materialise W⊙M vs fused matmul_nt_masked vs CSR spmm_nt",
        &["shape", "sparsity", "materialise", "fused", "csr", "fused/mat", "csr/fused"],
    );
    let mut rng = Rng::new(43);
    for (n, k, m) in [(256usize, 256usize, 256usize), (512, 512, 512)] {
        let x = Tensor::randn(&[n, k], 1.0, &mut rng);
        let w = Tensor::randn(&[m, k], 1.0, &mut rng);
        // |N(0,1)| quantiles: 0.6745 prunes ~50%, 1.6449 prunes ~90%
        for threshold in [0.6745f32, 1.6449] {
            let mask = Tensor::randn(&[m, k], 1.0, &mut rng)
                .map(|v| if v.abs() < threshold { 0.0 } else { 1.0 });
            let csr = sparse::CsrMatrix::from_dense_masked(&w, &mask);
            let a = bench.run(|| {
                let wm = w.hadamard(&mask);
                std::hint::black_box(linalg::matmul_nt(&x, &wm));
            });
            let b = bench.run(|| {
                std::hint::black_box(linalg::matmul_nt_masked(&x, &w, &mask));
            });
            let c = bench.run(|| {
                std::hint::black_box(sparse::spmm_nt(&x, &csr));
            });
            t.row(vec![
                format!("{n}x{k} @ ({m}x{k})T"),
                format!("{:.0}%", 100.0 * mask.zero_fraction()),
                fmt_duration(a.mean),
                fmt_duration(b.mean),
                fmt_duration(c.mean),
                format!("{:.2}x", a.mean_secs() / b.mean_secs()),
                format!("{:.2}x", b.mean_secs() / c.mean_secs()),
            ]);
        }
    }
    t.print();
    out.push(t);
}

fn main() {
    let mut tables = Vec::new();
    matmul_speedups(&mut tables);
    masked_matmul_ab(&mut tables);

    let rt = open_default_backend().expect("opening backend");
    let model = common::bench_model();
    let cfg = ExperimentConfig::quick(&model);
    let s = Session::new(rt.as_ref(), cfg, 0).unwrap();
    let mm = s.mm.clone();
    let b = mm.cfg.eval_batch;
    let sl = mm.cfg.seq_len;
    let shape = [b, sl];
    let tokens = s.train.eval_batch(b, 0);

    // prepare times (cold) — compilation on PJRT, validation on native
    let mut compile_t = Table::new(
        &format!("{} prepare time ({model})", rt.kind()),
        &["executable", "inputs", "prepare"],
    );
    for exec in ["eval_loss", "score", "train_full", "train_masklora", "calib_stats"] {
        let spec = mm.exec(exec).unwrap();
        let t0 = std::time::Instant::now();
        rt.prepare(&model, exec).unwrap();
        compile_t.row(vec![
            exec.to_string(),
            format!("{}", spec.inputs.len()),
            fmt_duration(t0.elapsed()),
        ]);
    }
    compile_t.print();
    tables.push(compile_t);

    // steady-state execution latency
    let bench = Bench::quick();
    let mut exec_t = Table::new(
        &format!("{} execution latency ({model}, batch {b}x{sl})", rt.kind()),
        &["executable", "mean", "p95", "tokens/s"],
    );
    for exec in ["eval_loss", "score", "calib_stats"] {
        let stats = bench.run(|| {
            let mut feed = base_feed(&s.params, &s.masks).ints("tokens", &shape, &tokens);
            if exec == "score" {
                feed = feed.owned("tmask", Tensor::ones(&[b, sl]));
            }
            std::hint::black_box(rt.run(&model, exec, &feed).unwrap());
        });
        exec_t.row(vec![
            exec.to_string(),
            fmt_duration(stats.mean),
            fmt_duration(stats.p95),
            format!("{:.0}", (b * sl) as f64 / stats.mean_secs()),
        ]);
    }
    exec_t.print();
    tables.push(exec_t);

    // tape-buffer reuse: the same train step with the thread-local pool
    // disabled (fresh allocations every step, the pre-pool behaviour) vs
    // enabled — the "on" row must not regress, and typically wins once the
    // first step has populated the pool
    let leaves = mm.trainable["biases"].clone();
    let opt = OptState::zeros(leaves.iter().map(|n| (n.as_str(), mm.param_shape(n))));
    let tb = mm.cfg.train_batch;
    let tshape = [tb, sl];
    let mut rng = Rng::new(7);
    let train_tokens = s.train.train_batch(tb, &mut rng);
    let mut pool_t = Table::new(
        &format!("train_biases step ({model}): tape pool off vs on"),
        &["pool", "mean", "p95", "pool hits"],
    );
    for on in [false, true] {
        pool::set_enabled(on);
        let (h0, _) = pool::stats();
        let stats = bench.run(|| {
            let mut feed = base_feed(&s.params, &s.masks)
                .ints("tokens", &tshape, &train_tokens)
                .scalar("step", 1.0)
                .scalar("lr", 1e-3);
            for n in &leaves {
                feed = feed
                    .tensor(&format!("om::{n}"), &opt.m[n])
                    .tensor(&format!("ov::{n}"), &opt.v[n]);
            }
            std::hint::black_box(rt.run(&model, "train_biases", &feed).unwrap());
        });
        let (h1, _) = pool::stats();
        pool_t.row(vec![
            if on { "on" } else { "off" }.to_string(),
            fmt_duration(stats.mean),
            fmt_duration(stats.p95),
            format!("{}", h1 - h0),
        ]);
    }
    pool::set_enabled(true);
    pool_t.print();
    tables.push(pool_t);

    std::fs::create_dir_all("results").ok();
    for t in &tables {
        t.append_to(std::path::Path::new("results/bench_tables.md")).ok();
    }
    println!("{} executions on the {} backend", rt.exec_count(), rt.kind());
}
