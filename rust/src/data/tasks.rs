//! Zero-shot task suite: seven likelihood-ranking tasks generated from the
//! same grammar as the corpus (EleutherAI-harness substitute).
//!
//! Every item is (context, k options, correct index); the evaluator scores
//! each option by length-normalised sum log-prob through the `score`
//! executable and picks the argmax — the exact mechanics of the harness
//! tasks in the paper (BoolQ, RTE, HellaSwag, WinoGrande, ARC-e/c, OBQA).
//!
//! The analogues vary, like the originals, in option count, continuation
//! length and distractor hardness:
//!
//! | task   | options | continuation | distractor                       |
//! |--------|---------|--------------|----------------------------------|
//! | boolq  | 2       | short        | wrong-topic walk                 |
//! | rte    | 2       | medium       | shuffled true continuation       |
//! | hswag  | 4       | long         | wrong-topic walks                |
//! | winog  | 2       | 1 word       | random successor-swap            |
//! | arc-e  | 4       | short        | unigram babble (easy)            |
//! | arc-c  | 4       | short        | same-topic offset walk (hard)    |
//! | obqa   | 4       | medium       | mixed                            |
//!
//! A converged dense model scores far above chance on the easy tasks and
//! modestly above on the hard ones; damage + recovery tracks the paper's
//! accuracy columns.

use crate::util::rng::Rng;

use super::corpus::Corpus;

pub const TASK_NAMES: [&str; 7] =
    ["boolq", "rte", "hswag", "winog", "arc-e", "arc-c", "obqa"];

#[derive(Debug, Clone)]
pub struct TaskItem {
    /// word-ids of the shared context
    pub context: Vec<u32>,
    /// word-ids per option (continuations)
    pub options: Vec<Vec<u32>>,
    pub correct: usize,
}

#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    pub items: Vec<TaskItem>,
}

pub fn build_suite(corpus: &Corpus, items_per_task: usize, seed: u64) -> Vec<Task> {
    TASK_NAMES
        .iter()
        .enumerate()
        .map(|(i, name)| Task {
            name: name.to_string(),
            items: (0..items_per_task)
                .map(|j| gen_item(corpus, name, &mut Rng::new(seed ^ ((i as u64) << 32 | j as u64))))
                .collect(),
        })
        .collect()
}

fn gen_item(c: &Corpus, task: &str, rng: &mut Rng) -> TaskItem {
    match task {
        "boolq" => continuation_item(c, rng, 24, 6, 2, Distractor::WrongTopic),
        "rte" => continuation_item(c, rng, 20, 8, 2, Distractor::Shuffle),
        "hswag" => continuation_item(c, rng, 24, 12, 4, Distractor::WrongTopic),
        "winog" => continuation_item(c, rng, 16, 1, 2, Distractor::SuccessorSwap),
        "arc-e" => continuation_item(c, rng, 16, 5, 4, Distractor::Unigram),
        "arc-c" => continuation_item(c, rng, 16, 5, 4, Distractor::SameTopicOffset),
        "obqa" => continuation_item(c, rng, 20, 8, 4, Distractor::Mixed),
        other => panic!("unknown task {other:?}"),
    }
}

enum Distractor {
    /// continue under a different topic's kernel
    WrongTopic,
    /// shuffle the words of the true continuation
    Shuffle,
    /// replace each word with a different successor of its predecessor
    SuccessorSwap,
    /// iid unigram draws (easy to reject)
    Unigram,
    /// a same-topic walk from a different anchor (hard to reject)
    SameTopicOffset,
    /// rotate through the other kinds
    Mixed,
}

fn continuation_item(
    c: &Corpus,
    rng: &mut Rng,
    ctx_len: usize,
    cont_len: usize,
    n_options: usize,
    kind: Distractor,
) -> TaskItem {
    let topic = rng.below(c.n_topics() as u64) as usize;
    let full = c.gen_doc_with_topic(ctx_len + cont_len, topic, rng);
    let context = full[..ctx_len].to_vec();
    let truth = full[ctx_len..].to_vec();

    let correct = rng.below(n_options as u64) as usize;
    let mut options = Vec::with_capacity(n_options);
    for i in 0..n_options {
        if i == correct {
            options.push(truth.clone());
            continue;
        }
        let d = match kind {
            Distractor::Mixed => match i % 3 {
                0 => Distractor::WrongTopic,
                1 => Distractor::Unigram,
                _ => Distractor::Shuffle,
            },
            Distractor::WrongTopic => Distractor::WrongTopic,
            Distractor::Shuffle => Distractor::Shuffle,
            Distractor::SuccessorSwap => Distractor::SuccessorSwap,
            Distractor::Unigram => Distractor::Unigram,
            Distractor::SameTopicOffset => Distractor::SameTopicOffset,
        };
        options.push(make_distractor(c, rng, topic, &context, &truth, d));
    }
    TaskItem { context, options, correct }
}

fn make_distractor(
    c: &Corpus,
    rng: &mut Rng,
    topic: usize,
    context: &[u32],
    truth: &[u32],
    kind: Distractor,
) -> Vec<u32> {
    let len = truth.len();
    match kind {
        Distractor::WrongTopic => {
            let other = (topic + 1 + rng.below((c.n_topics() - 1) as u64) as usize) % c.n_topics();
            let mut cur = *context.last().unwrap();
            (0..len)
                .map(|_| {
                    cur = c.next_word(other, cur, rng);
                    cur
                })
                .collect()
        }
        Distractor::Shuffle => {
            let mut v = truth.to_vec();
            if v.len() > 1 {
                // rotate to guarantee a change even if shuffle is identity
                rng.shuffle(&mut v);
                if v == truth {
                    v.rotate_left(1);
                }
            } else {
                v[0] = v[0].wrapping_add(1) % c.cfg.n_words as u32;
            }
            v
        }
        Distractor::SuccessorSwap => {
            // a plausible-but-different successor of the same predecessor
            let prev = *context.last().unwrap();
            let mut w = c.next_word(topic, prev, rng);
            let mut guard = 0;
            while [w] == truth[..1.min(truth.len())] && guard < 8 {
                w = c.next_word(topic, prev, rng);
                guard += 1;
            }
            let mut out = vec![w];
            let mut cur = w;
            for _ in 1..len {
                cur = c.next_word(topic, cur, rng);
                out.push(cur);
            }
            out
        }
        Distractor::Unigram => (0..len)
            .map(|_| rng.below(c.cfg.n_words as u64) as u32)
            .collect(),
        Distractor::SameTopicOffset => {
            // same topic, but restarted from a random anchor word
            let mut cur = rng.below(c.cfg.n_words as u64) as u32;
            (0..len)
                .map(|_| {
                    cur = c.next_word(topic, cur, rng);
                    cur
                })
                .collect()
        }
        Distractor::Mixed => unreachable!("resolved by caller"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn suite() -> (Corpus, Vec<Task>) {
        let c = Corpus::generate(CorpusConfig::for_vocab(128, 3));
        let s = build_suite(&c, 10, 42);
        (c, s)
    }

    #[test]
    fn suite_shape() {
        let (_, s) = suite();
        assert_eq!(s.len(), 7);
        for t in &s {
            assert_eq!(t.items.len(), 10);
            for item in &t.items {
                assert!(item.correct < item.options.len());
                let truth_len = item.options[item.correct].len();
                for o in &item.options {
                    assert_eq!(o.len(), truth_len, "options must be same length");
                }
            }
        }
    }

    #[test]
    fn option_counts_match_task_design() {
        let (_, s) = suite();
        let by_name: std::collections::HashMap<_, _> =
            s.iter().map(|t| (t.name.as_str(), t)).collect();
        assert_eq!(by_name["boolq"].items[0].options.len(), 2);
        assert_eq!(by_name["hswag"].items[0].options.len(), 4);
        assert_eq!(by_name["winog"].items[0].options[0].len(), 1);
    }

    #[test]
    fn distractors_differ_from_truth() {
        let (_, s) = suite();
        let mut diffs = 0;
        let mut total = 0;
        for t in &s {
            for item in &t.items {
                for (i, o) in item.options.iter().enumerate() {
                    if i != item.correct {
                        total += 1;
                        if o != &item.options[item.correct] {
                            diffs += 1;
                        }
                    }
                }
            }
        }
        // stochastic generators may rarely coincide; near-all must differ
        assert!(diffs as f64 / total as f64 > 0.95, "{diffs}/{total}");
    }

    #[test]
    fn deterministic_by_seed() {
        let c = Corpus::generate(CorpusConfig::for_vocab(128, 3));
        let a = build_suite(&c, 5, 1);
        let b = build_suite(&c, 5, 1);
        assert_eq!(a[0].items[0].context, b[0].items[0].context);
        assert_eq!(a[0].items[0].correct, b[0].items[0].correct);
    }
}
