//! Leveled stderr logging, the serialized stdout progress sink, and
//! wall-clock scoped timers.
//!
//! `PERP_LOG=debug|info|warn|off` controls verbosity (default info;
//! `off` silences everything including progress lines — handy for
//! benches).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    /// Threshold-only level: nothing logs at or above it.
    Off = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("PERP_LOG").as_deref() {
        Ok("debug") => 0,
        Ok("warn") => 2,
        Ok("off") => 3,
        _ => 1,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l != Level::Off && l as u8 >= level()
}

pub fn log(l: Level, msg: &str) {
    if enabled(l) {
        let tag = match l {
            Level::Debug => "DBG",
            Level::Info => "INF",
            Level::Warn => "WRN",
            Level::Off => return,
        };
        eprintln!("[{tag}] {msg}");
    }
}

/// One process-wide lock so concurrent workers emit whole progress lines
/// (the parallel plan executor shares it through this sink).
static PROGRESS: Mutex<()> = Mutex::new(());

/// Progress lines go to **stdout** (they are part of the command's
/// conversational output and CI greps them there), serialized under one
/// lock and gated at info level — `PERP_LOG=off` runs silent.
pub fn progress(msg: &str) {
    if enabled(Level::Info) {
        let _guard = PROGRESS.lock().unwrap_or_else(|e| e.into_inner());
        println!("{msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($arg)*)) };
}

/// RAII scope timer: logs `<name>: <elapsed>` at info level on drop and
/// doubles as an `obs::trace` span when tracing is on.  When *neither*
/// sink is live the timer holds no name at all — creating and dropping it
/// never formats or allocates (construct via [`crate::scope_timer!`]).
pub struct ScopeTimer {
    name: Option<String>,
    start: Instant,
    _span: crate::obs::trace::Span,
}

impl ScopeTimer {
    pub fn new(name: &str) -> Self {
        let span = if crate::obs::trace::enabled() {
            crate::obs::trace::Span::start("timer", name)
        } else {
            crate::obs::trace::Span::off()
        };
        ScopeTimer {
            name: enabled(Level::Info).then(|| name.to_string()),
            start: Instant::now(),
            _span: span,
        }
    }

    /// Macro back-end: `name` is `None` when both logging and tracing are
    /// disabled, so no string was ever formatted.
    pub fn from_parts(name: Option<String>) -> Self {
        match name {
            Some(n) => ScopeTimer::new(&n),
            None => ScopeTimer {
                name: None,
                start: Instant::now(),
                _span: crate::obs::trace::Span::off(),
            },
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if let Some(name) = self.name.take() {
            log(Level::Info, &format!("{}: {:.2}s", name, self.elapsed_secs()));
        }
    }
}

/// `span!`-style scoped timing: `let _t = scope_timer!("prune {}", m);`
/// logs the elapsed time on drop and opens an `obs::trace` span while
/// tracing is on.  Format arguments are not evaluated when both logging
/// and tracing are disabled.
#[macro_export]
macro_rules! scope_timer {
    ($($fmt:tt)*) => {
        $crate::util::logging::ScopeTimer::from_parts(
            if $crate::util::logging::enabled($crate::util::logging::Level::Info)
                || $crate::obs::trace::enabled()
            {
                Some(format!($($fmt)*))
            } else {
                None
            },
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::obs::trace::TEST_GATE as GATE;

    #[test]
    fn level_gating() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        set_level(Level::Off);
        assert!(!enabled(Level::Warn));
        assert!(!enabled(Level::Off), "Off is a threshold, never a log level");
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        set_level(Level::Warn);
    }

    #[test]
    fn timer_measures() {
        let t = ScopeTimer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_secs() >= 0.004);
    }

    #[test]
    fn disabled_timer_skips_formatting() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        let prev = if enabled(Level::Info) { Level::Info } else { Level::Warn };
        set_level(Level::Off);
        let t = crate::scope_timer!("never-{}", "formatted");
        assert!(t.name.is_none(), "no name may be formatted while off");
        drop(t);
        set_level(prev);
    }
}
