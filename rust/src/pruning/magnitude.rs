//! Magnitude pruning: remove the smallest-|w| weights.
//!
//! Two allocation schemes, per the paper's Appendix A.2:
//!
//! * **uniform** (the LLM default, following Sun et al. 2023): each prunable
//!   tensor is pruned by the same relative amount;
//! * **global** (the vision default): all prunable weights form one pool and
//!   share a single threshold.
//!
//! N:M semi-structured magnitude masks delegate to [`super::semistructured`].

use std::collections::BTreeMap;

use crate::tensor::Tensor;

use super::{mask_smallest_k, MaskSet, Pattern};

/// Uniform per-tensor magnitude masks.
pub fn uniform(weights: &BTreeMap<String, &Tensor>, pattern: Pattern) -> MaskSet {
    let mut out = MaskSet::default();
    for (name, w) in weights {
        let mask = match pattern {
            Pattern::Unstructured(f) => {
                let k = (f * w.numel() as f64).round() as usize;
                Tensor::new(w.shape(), mask_smallest_k(w.data(), k))
            }
            Pattern::SemiStructured { n, m } => super::semistructured::nm_mask(w, n, m),
        };
        out.set(name, mask);
    }
    out
}

/// Global magnitude masks: one |w| threshold across all prunable tensors.
pub fn global(weights: &BTreeMap<String, &Tensor>, sparsity: f64) -> MaskSet {
    let total: usize = weights.values().map(|w| w.numel()).sum();
    let k = (sparsity * total as f64).round() as usize;
    // collect (|w|, tensor idx, flat idx) and select the k smallest
    let mut mags: Vec<(f32, u32, u32)> = Vec::with_capacity(total);
    for (ti, (_, w)) in weights.iter().enumerate() {
        for (fi, &x) in w.data().iter().enumerate() {
            mags.push((x.abs(), ti as u32, fi as u32));
        }
    }
    mags.select_nth_unstable_by(k.min(total.saturating_sub(1)), |a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then((a.1, a.2).cmp(&(b.1, b.2)))
    });
    let mut masks: Vec<Tensor> = weights.values().map(|w| Tensor::ones(w.shape())).collect();
    for &(_, ti, fi) in &mags[..k] {
        masks[ti as usize].data_mut()[fi as usize] = 0.0;
    }
    let mut out = MaskSet::default();
    for ((name, _), mask) in weights.iter().zip(masks) {
        out.set(name, mask);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn weights(rng: &mut Rng) -> (Vec<Tensor>, BTreeMap<String, &'static Tensor>) {
        // leak for 'static simplicity in tests
        let a = Box::leak(Box::new(Tensor::randn(&[8, 16], 1.0, rng)));
        let b = Box::leak(Box::new(Tensor::randn(&[4, 32], 0.1, rng)));
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), &*a);
        m.insert("b".to_string(), &*b);
        (vec![], m)
    }

    #[test]
    fn uniform_hits_exact_fraction() {
        let mut rng = Rng::new(1);
        let (_, w) = weights(&mut rng);
        let ms = uniform(&w, Pattern::Unstructured(0.5));
        for (_, s) in ms.per_layer_sparsity() {
            assert!((s - 0.5).abs() < 1e-6, "{s}");
        }
    }

    #[test]
    fn global_shares_threshold() {
        let mut rng = Rng::new(2);
        let (_, w) = weights(&mut rng);
        // tensor "b" has 10x smaller weights — global pruning should hit it
        // much harder than "a"
        let ms = global(&w, 0.5);
        assert!((ms.sparsity() - 0.5).abs() < 1e-2, "{}", ms.sparsity());
        let per: BTreeMap<_, _> = ms.per_layer_sparsity().into_iter().collect();
        assert!(per["b"] > 0.8, "b sparsity {}", per["b"]);
        assert!(per["a"] < 0.3, "a sparsity {}", per["a"]);
    }

    #[test]
    fn prop_uniform_keeps_largest() {
        prop::check("uniform_keeps_largest", 20, |g| {
            let rows = g.dim(8).max(1);
            let cols = g.dim(32).max(2);
            let sp = g.sparsity();
            let t = Tensor::new(&[rows, cols], g.tensor(rows * cols, 1.0));
            let mut m = BTreeMap::new();
            m.insert("w".to_string(), &t);
            let ms = uniform(&m, Pattern::Unstructured(sp as f64));
            let mask = ms.get("w");
            let k = (sp as f64 * t.numel() as f64).round() as usize;
            assert_eq!(mask.count(|x| x == 0.0), k);
        });
    }

    #[test]
    fn semistructured_dispatch() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let mut m = BTreeMap::new();
        m.insert("w".to_string(), &t);
        let ms = uniform(&m, Pattern::SemiStructured { n: 2, m: 4 });
        assert!((ms.sparsity() - 0.5).abs() < 1e-9);
    }
}
