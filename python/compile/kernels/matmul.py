"""Tiled matmul Pallas kernels — the dense building blocks.

Two layouts cover every contraction in the model and its backward pass:

* ``mm_nt(a, b) = a @ b.T``  for a:(n,k), b:(m,k) — the linear-layer forward
  (weights stored (out, in)) and the dZ = g^T @ x gradient (via transposes).
* ``mm_nn(a, b) = a @ b``    for a:(n,k), b:(k,m) — the dx = g @ Z gradient.

Both use the canonical TPU accumulation pattern: a VMEM scratch accumulator,
zeroed on the first k-step of the grid and flushed to the output tile on the
last.  ``interpret=True`` lowers this to plain HLO (see common.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, MatmulBlocks, cdiv, scratch


def _mm_nt_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mm_nt(x: jax.Array, w: jax.Array) -> jax.Array:
    """x:(n,k) @ w:(m,k)^T -> (n,m)."""
    n, k = x.shape
    m, k2 = w.shape
    assert k == k2, (x.shape, w.shape)
    blk = MatmulBlocks.choose(n, m, k)
    nk = cdiv(k, blk.bk)
    return pl.pallas_call(
        functools.partial(_mm_nt_kernel, nk=nk),
        grid=(cdiv(n, blk.bn), cdiv(m, blk.bm), nk),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((blk.bm, blk.bk), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(x, w)


def _mm_nn_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def mm_nn(x: jax.Array, w: jax.Array) -> jax.Array:
    """x:(n,k) @ w:(k,m) -> (n,m)."""
    n, k = x.shape
    k2, m = w.shape
    assert k == k2, (x.shape, w.shape)
    blk = MatmulBlocks.choose(n, m, k)
    nk = cdiv(k, blk.bk)
    return pl.pallas_call(
        functools.partial(_mm_nn_kernel, nk=nk),
        grid=(cdiv(n, blk.bn), cdiv(m, blk.bm), nk),
        in_specs=[
            pl.BlockSpec((blk.bn, blk.bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((blk.bk, blk.bm), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((blk.bn, blk.bm), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        scratch_shapes=[scratch((blk.bn, blk.bm))],
        interpret=INTERPRET,
    )(x, w)


# ---------------------------------------------------------------------------
# Differentiable masked linear built from the kernels above: the pruned-layer
# forward used everywhere a frozen-sparse weight appears.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def masked_matmul(x, w, mask):
    """y = x @ (W*M)^T with pallas fwd and bwd."""
    return mm_nt(x, w * mask)


def _masked_matmul_fwd(x, w, mask):
    return mm_nt(x, w * mask), (x, w, mask)


def _masked_matmul_bwd(res, g):
    x, w, mask = res
    weff = w * mask
    dx = mm_nn(g, weff)
    # dW = (g^T @ x) ⊙ M — contraction expressed through mm_nt on transposes.
    dw = mm_nt(g.T, x.T) * mask
    return dx, dw, None


masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)


# ---------------------------------------------------------------------------
# Differentiable dense matmul (used for the never-pruned head and the classic
# LoRA low-rank path, where grads flow to both operands).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def dmm_nt(x, w):
    """Differentiable y = x @ W^T, pallas fwd and bwd."""
    return mm_nt(x, w)


def _dmm_nt_fwd(x, w):
    return mm_nt(x, w), (x, w)


def _dmm_nt_bwd(res, g):
    x, w = res
    return mm_nn(g, w), mm_nt(g.T, x.T)


dmm_nt.defvjp(_dmm_nt_fwd, _dmm_nt_bwd)
