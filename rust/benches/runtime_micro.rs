//! Micro-benchmarks of the PJRT bridge itself: compile time per executable
//! and steady-state execution latency of the hot-path graphs.  Feeds the
//! §Perf analysis of where retraining wall-clock goes (host<->device copies
//! vs device compute).

mod common;

use perp::config::ExperimentConfig;
use perp::coordinator::Session;
use perp::eval::base_feed;
use perp::runtime::{default_artifacts_dir, Runtime};
use perp::util::bench::{fmt_duration, Bench, Table};

fn main() {
    let rt = Runtime::new(&default_artifacts_dir()).expect("make artifacts first");
    let model = common::bench_model();
    let cfg = ExperimentConfig::quick(&model);
    let s = Session::new(&rt, cfg, 0).unwrap();
    let mm = s.mm.clone();
    let b = mm.cfg.eval_batch;
    let sl = mm.cfg.seq_len;
    let shape = [b, sl];
    let tokens = s.train.eval_batch(b, 0);

    // compile times (cold)
    let mut compile_t = Table::new(
        &format!("PJRT compile time ({model})"),
        &["executable", "inputs", "HLO file", "compile"],
    );
    for exec in ["eval_loss", "score", "train_full", "train_masklora", "calib_stats"] {
        let spec = mm.exec(exec).unwrap();
        let bytes = std::fs::metadata(rt.manifest.hlo_path(spec)).map(|m| m.len()).unwrap_or(0);
        let t0 = std::time::Instant::now();
        rt.load(&model, exec).unwrap();
        compile_t.row(vec![
            exec.to_string(),
            format!("{}", spec.inputs.len()),
            format!("{:.2} MB", bytes as f64 / 1e6),
            fmt_duration(t0.elapsed()),
        ]);
    }
    compile_t.print();

    // steady-state execution latency
    let bench = Bench::quick();
    let mut exec_t = Table::new(
        &format!("execution latency ({model}, batch {b}x{sl})"),
        &["executable", "mean", "p95", "tokens/s"],
    );
    for exec in ["eval_loss", "score", "calib_stats"] {
        let stats = bench.run(|| {
            let mut feed = base_feed(&s.params, &s.masks).ints("tokens", &shape, &tokens);
            if exec == "score" {
                feed = feed.owned("tmask", perp::tensor::Tensor::ones(&[b, sl]));
            }
            std::hint::black_box(rt.run(&model, exec, &feed).unwrap());
        });
        exec_t.row(vec![
            exec.to_string(),
            fmt_duration(stats.mean),
            fmt_duration(stats.p95),
            format!("{:.0}", (b * sl) as f64 / stats.mean_secs()),
        ]);
    }
    exec_t.print();
    std::fs::create_dir_all("results").ok();
    compile_t.append_to(std::path::Path::new("results/bench_tables.md")).ok();
    exec_t.append_to(std::path::Path::new("results/bench_tables.md")).ok();
}
