//! Sparsity sweep (Fig 1 shape): perplexity vs sparsity for every retrained
//! parameter subset, printed as an aligned series — written against the
//! `perp::pipeline` graph API.
//!
//! ```bash
//! cargo run --release --offline --example sparsity_sweep -- [--model gpt-nano]
//! ```
//!
//! The whole sweep is ONE plan graph: a single pretrain root, one prune
//! node per sparsity, and one retrain branch per method under each prune.
//! The executor walks it depth-first, snapshotting the session at every
//! fork — so the dense model converges once and each sparsity prunes once,
//! no matter how many methods fan out below.  Re-running the example loads
//! every node from the content-addressed cache.

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::peft::Mode;
use perp::pipeline::{Executor, PlanGraph, Stage};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::open_default_backend;
use perp::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).map_err(|e| anyhow::anyhow!(e))?;
    let model = args.str("model", "gpt-nano");
    let steps = args.u64("steps", 100)?;
    args.finish()?;

    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::quick(&model);
    cfg.pretrain_steps = 3000;

    let sparsities = [0.3, 0.4, 0.5, 0.6, 0.7];
    let methods: Vec<(&str, Option<Mode>)> = vec![
        ("no retraining", None),
        ("head", Some(Mode::Head)),
        ("embed", Some(Mode::Embed)),
        ("biases", Some(Mode::Biases)),
        ("ln", Some(Mode::Ln)),
        ("masklora", Some(Mode::MaskLora)),
        ("full ft", Some(Mode::Full)),
    ];

    // one graph, one shared prefix per sparsity
    let mut g = PlanGraph::new("sparsity-sweep");
    g.stage_node("pre", None, Stage::Pretrain);
    for sp in sparsities {
        let prune = format!("prune@{sp}");
        g.stage_node(&prune, Some("pre"), Stage::Prune {
            criterion: Criterion::Magnitude,
            pattern: Pattern::Unstructured(sp),
        });
        for (label, mode) in &methods {
            let mut tail = prune.clone();
            if let Some(m) = mode {
                let retrain = format!("{label}@{sp}:retrain");
                g.stage_node(&retrain, Some(&tail), Stage::Retrain {
                    mode: *m,
                    steps: Some(steps),
                    lr: Some(cfg.lr_grid[0]),
                });
                tail = retrain;
                if m.is_lora() && *m != Mode::Lora {
                    let merge = format!("{label}@{sp}:merge");
                    g.stage_node(&merge, Some(&tail), Stage::Merge);
                    tail = merge;
                }
            }
            g.stage_node(&format!("{label}@{sp}:eval"), Some(&tail), Stage::Eval { tasks: false });
        }
    }

    let ex = Executor::new(rt.as_ref(), cfg, "results/cache".into(), 0).quiet(true);
    let report = ex.run_graph(&g)?;
    eprintln!("{}", report.summary());

    print!("{:<16}", "method");
    for sp in sparsities {
        print!(" {:>8.0}%", sp * 100.0);
    }
    println!();

    for (label, _) in &methods {
        print!("{label:<16}");
        for sp in sparsities {
            let ppl = report
                .metrics(&format!("{label}@{sp}:eval"))
                .map(|m| m.ppl)
                .unwrap_or(f64::NAN);
            print!(" {ppl:>9.2}");
        }
        println!();
    }
    Ok(())
}
