//! The plan executor: a ready-set scheduler that drives a [`PlanGraph`]
//! (or a linear [`Plan`] — a single-path graph) over [`Session`]s with
//! content-addressed artifact caching, serially or across a worker pool.
//!
//! Every stage node writes its outputs under `<cache>/plan/<key>/` where
//! `key` is the FNV chain of (model, config, seed + node seed-offset,
//! backend, all root-path stages):
//!
//! | stage       | artifacts                                         |
//! |-------------|---------------------------------------------------|
//! | pretrain    | `meta.json` (weights live in the shared dense checkpoint cache) |
//! | prune       | `state.ptns`, `masks.ptns`, `meta.json` (sparsity) |
//! | retrain     | `state.ptns`, `masks.ptns`, [`lora.ptns`], `meta.json` (tps, trainable%) |
//! | reconstruct | `state.ptns`, `masks.ptns`, `meta.json` (mean layer-loss drop) |
//! | merge       | `state.ptns`, `masks.ptns`, `meta.json`           |
//! | eval        | `metrics.json` (ppl, acc, per-task, sparsity)     |
//! | export      | `meta.json` (content fingerprint of the written checkpoint) |
//!
//! **Fan-out.**  A node with several children executes once; before each
//! child but the last, the branch state (session weights/masks/adapters +
//! pending reconstruction targets) is snapshotted via
//! [`ExpContext::clone_session`] — so a fork over `{0.5, 0.7, 0.9}`
//! sparsities prunes three times but pretrains exactly once per run.
//! Across runs the content-addressed cache takes over: subtrees whose every
//! node is already complete are reported from their artifacts without even
//! materialising a session (zero backend executions on resume).
//!
//! **Parallelism.**  With `jobs > 1` the walk becomes a ready-set
//! scheduler: a frontier of nodes whose parents are complete is drained by
//! `jobs` scoped worker threads ([`std::thread::scope`]); each worker runs
//! a chain depth-first (queueing all but one live child at every fork) so
//! sibling subtrees execute concurrently.  Every in-flight node claims a
//! slice of the kernel thread budget ([`threads::acquire_share`]), so N
//! concurrent nodes split the rayon/CSR parallelism instead of
//! oversubscribing N×`PERP_THREADS`.  Concurrency never breaks the cache:
//! duplicate in-flight stage keys are serialized behind a per-key lock
//! (the second branch waits, then reads the artifacts as a hit), stage
//! dirs land via temp-dir + atomic rename (a killed run never leaves a
//! partial dir that later scans as complete), and [`GraphReport`] nodes
//! are ordered by the canonical depth-first topological order — not
//! completion order — so resumes, `computed_labeled` counts, and sweep
//! tables are byte-stable whatever `--jobs` was.  Capture runs (linear
//! shims that need the final session back) always walk serially.
//!
//! **Export idempotence.**  `export` records the FNV fingerprint of the
//! bytes it wrote; when the same node would write the identical checkpoint
//! over an unchanged file it skips the write and reports a cache hit.
//! Deleting or editing the target file (or `--force`) re-exports.
//!
//! `meta.json` / `metrics.json` are written last, so their presence marks a
//! complete stage within the staging dir; the whole dir then renames into
//! its content-addressed path in one step.  `force` ignores the stage
//! cache; the keyed dense pretrain checkpoint is still honoured because it
//! is deterministic in exactly the inputs the key hashes.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::reconstruct;
use crate::obs::counters::Registry;
use crate::coordinator::sweep::ExpContext;
use crate::coordinator::Session;
use crate::eval::{mean_std, MeanStd};
use crate::model::ParamStore;
use crate::peft::{LoraState, Mode};
use crate::pruning::MaskSet;
use crate::runtime::{Backend, ModelManifest};
use crate::tensor::{io, Tensor};
use crate::util::json::Json;
use crate::util::threads;

use super::cachekey::{fnv1a_hex, Key};
use super::graph::{Node, NodeKind, PlanGraph};
use super::plan::{Plan, Stage};

/// A graph run stopped early because its cancel flag flipped on (daemon
/// shutdown, job cancellation).  In-flight nodes finish and commit their
/// artifacts before the walk returns, so a later run resumes them as cache
/// hits — downcast with `err.downcast_ref::<Interrupted>()` to tell an
/// interruption from a real failure.
#[derive(Debug, Clone, thiserror::Error)]
#[error("plan graph run interrupted before node {node:?}")]
pub struct Interrupted {
    /// the node the walk was about to execute when it noticed the flag
    pub node: String,
}

/// Per-node lifecycle events delivered to an [`Executor::on_node`] hook.
/// `Started` fires when a node is claimed for execution (before the cache
/// hit-check); `Finished` fires once per node with its final report, on
/// both the compute and the cached-subtree paths.  Hooks run on executor
/// worker threads and must be cheap and non-blocking-ish (the job daemon
/// persists per-node status from here).
#[derive(Debug)]
pub enum NodeEvent<'a> {
    Started { name: &'a str, key: &'a str },
    Finished(&'a NodeReport),
}

/// Shared observer for [`NodeEvent`]s (`Arc` so parallel workers clone it).
pub type NodeHook = Arc<dyn Fn(NodeEvent<'_>) + Send + Sync>;

/// What an `eval` stage measured.
#[derive(Debug, Clone)]
pub struct EvalMetrics {
    pub ppl: f64,
    pub loss: f64,
    /// mean zero-shot accuracy; NaN when the stage ran perplexity-only
    pub acc: f64,
    pub per_task: Vec<(String, f64)>,
    /// achieved weight sparsity at evaluation time
    pub sparsity: f64,
}

/// Outcome of one stage node.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub label: String,
    /// 16-hex content address of this stage's artifacts
    pub key: String,
    pub cache_hit: bool,
    pub wall_s: f64,
    /// populated by `eval` stages
    pub metrics: Option<EvalMetrics>,
    /// populated by `prune` stages
    pub sparsity: Option<f64>,
    /// populated by `retrain` stages
    pub tps: Option<f64>,
    pub trainable_pct: Option<f64>,
    /// learning rate the retrain stage actually used (grid-tuned when the
    /// plan left it unpinned)
    pub lr: Option<f64>,
    /// populated by `reconstruct` stages
    pub mean_improvement: Option<f64>,
    /// global-registry counter deltas attributed to this node's execution
    /// (exact at `--jobs 1`; under parallelism concurrent nodes overlap).
    /// Loaded from the profile sidecar on cache hits; empty when the stage
    /// predates profiling.
    pub counters: BTreeMap<String, u64>,
    /// wall-clock of the *original* computation — `wall_s` on a miss, the
    /// sidecar-recorded value on a hit (where `wall_s` is just lookup time)
    pub computed_wall_s: Option<f64>,
}

impl StageReport {
    fn new(label: String, key: &Key) -> StageReport {
        StageReport {
            label,
            key: key.hex(),
            cache_hit: false,
            wall_s: 0.0,
            metrics: None,
            sparsity: None,
            tps: None,
            trainable_pct: None,
            lr: None,
            mean_improvement: None,
            counters: BTreeMap::new(),
            computed_wall_s: None,
        }
    }
}

/// One executed (or cache-resumed) graph node.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub name: String,
    pub parent: Option<String>,
    /// effective seed (executor seed + node seed offset)
    pub seed: u64,
    pub rep: StageReport,
}

/// One aggregate node's mean±std reduction over its leaf eval metrics.
#[derive(Debug, Clone)]
pub struct AggregateRow {
    pub name: String,
    pub over: Vec<String>,
    pub ppl: MeanStd,
    pub acc: MeanStd,
    pub sparsity: MeanStd,
}

/// Outcome of a graph run: every stage node in canonical topological
/// (depth-first) order — never completion order, so parallel and serial
/// runs report identically — plus the aggregate reductions.
#[derive(Debug, Clone)]
pub struct GraphReport {
    pub graph: String,
    pub nodes: Vec<NodeReport>,
    pub aggregates: Vec<AggregateRow>,
}

impl GraphReport {
    pub fn node(&self, name: &str) -> Option<&StageReport> {
        self.nodes.iter().find(|n| n.name == name).map(|n| &n.rep)
    }

    /// Metrics of the named eval node, if it ran.
    pub fn metrics(&self, name: &str) -> Option<&EvalMetrics> {
        self.node(name).and_then(|r| r.metrics.as_ref())
    }

    pub fn aggregate(&self, name: &str) -> Option<&AggregateRow> {
        self.aggregates.iter().find(|a| a.name == name)
    }

    pub fn cache_hits(&self) -> usize {
        self.nodes.iter().filter(|n| n.rep.cache_hit).count()
    }

    /// Nodes that actually computed (no cache hit) — the per-run exec
    /// counts the shared-prefix tests assert on.
    pub fn computed(&self) -> usize {
        self.nodes.len() - self.cache_hits()
    }

    /// Computed nodes whose stage label starts with `prefix` (e.g.
    /// `computed_labeled("pretrain")` must be ≤ 1 per seed within a run).
    pub fn computed_labeled(&self, prefix: &str) -> usize {
        self.nodes
            .iter()
            .filter(|n| !n.rep.cache_hit && n.rep.label.starts_with(prefix))
            .count()
    }

    pub fn summary(&self) -> String {
        format!(
            "graph {}: {}/{} nodes from cache",
            self.graph,
            self.cache_hits(),
            self.nodes.len()
        )
    }
}

/// Outcome of a linear plan run (a single-path graph, reported flat).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub plan: String,
    pub stages: Vec<StageReport>,
}

impl RunReport {
    pub fn cache_hits(&self) -> usize {
        self.stages.iter().filter(|s| s.cache_hit).count()
    }

    /// Metrics of the last `eval` stage, if any.
    pub fn last_metrics(&self) -> Option<&EvalMetrics> {
        self.stages.iter().rev().find_map(|s| s.metrics.as_ref())
    }

    /// All `eval` stage metrics in plan order.
    pub fn metrics(&self) -> Vec<&EvalMetrics> {
        self.stages.iter().filter_map(|s| s.metrics.as_ref()).collect()
    }

    pub fn summary(&self) -> String {
        format!(
            "plan {}: {}/{} stages from cache",
            self.plan,
            self.cache_hits(),
            self.stages.len()
        )
    }
}

/// A stage node's artifact directory under the results cache.
pub fn stage_dir(cache_dir: &Path, key: &Key) -> PathBuf {
    cache_dir.join("plan").join(key.hex())
}

/// Is this stage's artifact set complete on disk?  The static form of the
/// executor's per-stage hit check, shared with `repro plan show` (cache
/// status) and the cached-subtree fast path.  For `export` the "artifact"
/// is the target file itself: complete only when its bytes still match the
/// fingerprint recorded at export time.
pub fn stage_complete(dir: &Path, stage: &Stage) -> bool {
    match stage {
        Stage::Pretrain => dir.join("meta.json").is_file(),
        Stage::Export { path } => read_meta_str(dir, "content_fnv")
            .is_some_and(|h| file_fnv(Path::new(path)).as_deref() == Some(h.as_str())),
        Stage::Eval { .. } => dir.join("metrics.json").is_file(),
        Stage::Retrain { mode, .. } => {
            let mut needs = vec!["state.ptns", "masks.ptns", "meta.json"];
            if mode.is_lora() {
                needs.push("lora.ptns");
            }
            needs.iter().all(|f| dir.join(f).is_file())
        }
        Stage::Prune { .. } | Stage::Reconstruct { .. } | Stage::Merge => {
            ["state.ptns", "masks.ptns", "meta.json"]
                .iter()
                .all(|f| dir.join(f).is_file())
        }
    }
}

/// FNV-1a fingerprint of a file's bytes (None when unreadable/absent).
pub fn file_fnv(path: &Path) -> Option<String> {
    std::fs::read(path).ok().map(|b| fnv1a_hex(&b))
}

/// Everything one branch of the walk owns: the live session plus the dense
/// weights snapshotted at the most recent prune (Eq. 1 reconstruction
/// targets — `Arc` so forking a branch shares rather than copies them,
/// across worker threads).
struct Branch<'rt> {
    session: Session<'rt>,
    pre_prune: Option<Arc<BTreeMap<String, Tensor>>>,
}

/// A unit of scheduler work: a stage node plus the branch state flowing
/// into it (roots start from none).
type Task<'rt> = (String, Option<Branch<'rt>>);

/// Shared frontier of the parallel walk, behind one mutex: ready tasks
/// plus the count of tasks claimed-or-queued but not yet finished.
struct SchedState<'rt> {
    queue: VecDeque<Task<'rt>>,
    /// tasks queued or in flight; 0 ⇒ the run has drained, workers exit
    outstanding: usize,
    abort: bool,
}

/// Serialized progress sink: node completions (from any worker) go through
/// one lock, so lines never interleave mid-row and the `[done/total]`
/// counter is consistent.  `--quiet` drops everything.
struct Progress {
    quiet: bool,
    total: usize,
    done: Mutex<usize>,
}

impl Progress {
    fn new(total: usize, quiet: bool) -> Progress {
        Progress { quiet, total, done: Mutex::new(0) }
    }

    fn emit(&self, node: &str, rep: &StageReport) {
        if self.quiet {
            return;
        }
        let mut done = self.done.lock().unwrap_or_else(|p| p.into_inner());
        *done += 1;
        let status = if rep.cache_hit {
            "cache hit".to_string()
        } else {
            format!("done in {:.2}s", rep.wall_s)
        };
        crate::util::logging::progress(&format!(
            "[{}/{}] {:<14} {:<28} {} (key {})",
            *done,
            self.total,
            node,
            rep.label,
            status,
            &rep.key[..10]
        ));
    }
}

/// Per-run bookkeeping threaded through the serial walk.
struct GraphRun<'a, 'rt> {
    g: &'a PlanGraph,
    keys: &'a BTreeMap<String, Key>,
    /// node name → whole-subtree-complete, scanned once at run start (an
    /// `Export` completeness check hashes its target file, so re-deriving
    /// this per walk step would re-read checkpoints O(depth) times)
    complete: &'a BTreeMap<String, bool>,
    progress: &'a Progress,
    reports: Vec<NodeReport>,
    /// leaf node whose final session the caller wants back (linear shims);
    /// set ⇒ the cached-subtree fast path is disabled so the session always
    /// materialises
    capture: Option<String>,
    captured: Option<Session<'rt>>,
}

/// Drives plans and plan graphs over sessions.  Construct once per
/// (backend, config, base seed); run as many plans as you like — shared
/// prefixes share artifacts, and within one graph run they share live
/// session snapshots.
pub struct Executor<'rt> {
    rt: &'rt dyn Backend,
    cfg: ExperimentConfig,
    /// results cache root (also holds the dense checkpoint cache)
    cache_dir: PathBuf,
    seed: u64,
    force: bool,
    quiet: bool,
    /// worker threads for concurrent graph nodes (1 = the serial DFS walk)
    jobs: usize,
    /// per-stage-key execution locks: two branches needing the same node
    /// key execute it once — the second waits, then reads a cache hit
    key_locks: Mutex<BTreeMap<String, Arc<Mutex<()>>>>,
    /// external cancellation: checked before every node claim; when set the
    /// walk stops scheduling and `run_graph` returns [`Interrupted`]
    cancel: Option<Arc<AtomicBool>>,
    /// per-node lifecycle observer (the job daemon's progress persister)
    hook: Option<NodeHook>,
}

impl<'rt> Executor<'rt> {
    pub fn new(
        rt: &'rt dyn Backend,
        cfg: ExperimentConfig,
        cache_dir: PathBuf,
        seed: u64,
    ) -> Executor<'rt> {
        Executor {
            rt,
            cfg,
            cache_dir,
            seed,
            force: false,
            quiet: false,
            jobs: 1,
            key_locks: Mutex::new(BTreeMap::new()),
            cancel: None,
            hook: None,
        }
    }

    /// Ignore completed stage artifacts and recompute everything.
    pub fn force(mut self, force: bool) -> Self {
        self.force = force;
        self
    }

    /// Suppress per-stage progress lines (sweeps drive many small plans).
    pub fn quiet(mut self, quiet: bool) -> Self {
        self.quiet = quiet;
        self
    }

    /// Concurrent graph nodes (`--jobs`).  1 keeps the serial depth-first
    /// walk; N > 1 schedules ready subtrees over N workers which split the
    /// kernel thread budget between them.  Reports, artifacts and metrics
    /// are bitwise-identical either way.
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Cooperative cancellation: when `flag` flips on mid-run, the walk
    /// stops claiming new nodes (in-flight nodes finish and commit) and
    /// `run_graph` returns an [`Interrupted`] error.
    pub fn cancel_flag(mut self, flag: Arc<AtomicBool>) -> Self {
        self.cancel = Some(flag);
        self
    }

    /// Observe per-node lifecycle events (see [`NodeEvent`]).
    pub fn on_node(mut self, hook: NodeHook) -> Self {
        self.hook = Some(hook);
        self
    }

    fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// One node finished (computed or cache-reported): progress line + hook.
    fn notify_done(&self, progress: &Progress, nrep: &NodeReport) {
        progress.emit(&nrep.name, &nrep.rep);
        if let Some(h) = &self.hook {
            h(NodeEvent::Finished(nrep));
        }
    }

    // ------------------------------------------------------------------
    // Linear plans: thin wrappers over the graph scheduler.
    // ------------------------------------------------------------------

    pub fn run(&self, plan: &Plan) -> Result<RunReport> {
        self.run_linear(plan, false).map(|(report, _)| report)
    }

    /// Run a plan, returning the report plus the final session state (the
    /// CLI shims print from it).
    pub fn run_with_session(&self, plan: &Plan) -> Result<(RunReport, Session<'rt>)> {
        let (report, session) = self.run_linear(plan, true)?;
        Ok((report, session.expect("capture requested: session materialised")))
    }

    fn run_linear(&self, plan: &Plan, capture: bool) -> Result<(RunReport, Option<Session<'rt>>)> {
        plan.validate()
            .map_err(|e| anyhow::anyhow!("invalid plan {:?}: {e}", plan.name))?;
        let g = plan.to_graph();
        let leaf = format!("s{}", plan.stages.len());
        let (graph_report, session) = self.run_graph_inner(&g, capture.then_some(leaf))?;
        let stages = graph_report.nodes.into_iter().map(|n| n.rep).collect();
        Ok((RunReport { plan: plan.name.clone(), stages }, session))
    }

    // ------------------------------------------------------------------
    // Graph scheduling.
    // ------------------------------------------------------------------

    pub fn run_graph(&self, g: &PlanGraph) -> Result<GraphReport> {
        self.run_graph_inner(g, None).map(|(report, _)| report)
    }

    fn run_graph_inner(
        &self,
        g: &PlanGraph,
        capture: Option<String>,
    ) -> Result<(GraphReport, Option<Session<'rt>>)> {
        g.validate()
            .map_err(|e| anyhow::anyhow!("invalid plan graph {:?}: {e}", g.name))?;
        let _run_span = crate::span!("plan", "graph {}", g.name)
            .arg("jobs", self.jobs)
            .arg("nodes", g.stage_count());
        let keys = g
            .node_keys(&self.cfg, self.seed)
            .map_err(|e| anyhow::anyhow!("keying plan graph {:?}: {e}", g.name))?;
        let ctx = ExpContext::new(self.rt, self.cfg.clone(), self.cache_dir.clone());
        // pre-scan completeness only when the fast path can fire at all:
        // --force walks everything, and a capture run must materialise
        // sessions regardless
        let mut complete = BTreeMap::new();
        if capture.is_none() && !self.force {
            for root in g.roots() {
                self.scan_complete(g, &keys, root, &mut complete);
            }
        }
        let progress = Progress::new(g.stage_count(), self.quiet);
        let (mut reports, captured) = if self.jobs > 1 && capture.is_none() {
            (self.parallel_walk(&ctx, g, &keys, &complete, &progress)?, None)
        } else {
            let mut run = GraphRun {
                g,
                keys: &keys,
                complete: &complete,
                progress: &progress,
                reports: Vec::with_capacity(g.stage_count()),
                capture,
                captured: None,
            };
            for root in g.roots() {
                if self.subtree_complete(run.complete, root) {
                    self.emit_cached_subtree(g, &keys, &progress, root, &mut run.reports)?;
                } else {
                    self.walk(&ctx, &mut run, root, None)?;
                }
            }
            (run.reports, run.captured)
        };
        // canonical topological order regardless of completion order, so
        // serial and parallel runs (and resumes) report byte-identically
        let order = dfs_order(g);
        reports.sort_by_key(|r| order.get(&r.name).copied().unwrap_or(usize::MAX));
        let aggregates = self.reduce_aggregates(g, &reports)?;
        let report = GraphReport { graph: g.name.clone(), nodes: reports, aggregates };
        Ok((report, captured))
    }

    /// Serial walk: execute `node`, then descend into its children,
    /// snapshotting the branch before every child but the last (the last
    /// inherits it).
    fn walk(
        &self,
        ctx: &ExpContext<'rt>,
        run: &mut GraphRun<'_, 'rt>,
        node: &Node,
        incoming: Option<Branch<'rt>>,
    ) -> Result<()> {
        if self.cancelled() {
            return Err(Interrupted { node: node.name.clone() }.into());
        }
        let (nrep, branch) = self.exec_node(ctx, run.g, run.keys, node, incoming)?;
        self.notify_done(run.progress, &nrep);
        run.reports.push(nrep);
        let g = run.g;
        // fully-cached child subtrees are reported from their artifacts
        // without a session — no snapshot, no backend work
        let mut live: Vec<&Node> = Vec::new();
        for child in g.children(&node.name) {
            if self.subtree_complete(run.complete, child) {
                self.emit_cached_subtree(g, run.keys, run.progress, child, &mut run.reports)?;
            } else {
                live.push(child);
            }
        }
        if live.is_empty() {
            if run.capture.as_deref() == Some(node.name.as_str()) {
                run.captured = Some(branch.session);
            }
            return Ok(());
        }
        let mut branch = Some(branch);
        let n_live = live.len();
        for (i, child) in live.into_iter().enumerate() {
            let b = if i + 1 < n_live {
                self.snapshot(ctx, branch.as_ref().expect("branch moves only at the last child"))?
            } else {
                branch.take().expect("last child takes the branch")
            };
            self.walk(ctx, run, child, Some(b))?;
        }
        Ok(())
    }

    /// Parallel walk: a ready-set scheduler.  Roots seed the frontier;
    /// `jobs` scoped workers drain it, each running a chain depth-first
    /// and queueing the other live children of every fork.
    fn parallel_walk(
        &self,
        ctx: &ExpContext<'rt>,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        complete: &BTreeMap<String, bool>,
        progress: &Progress,
    ) -> Result<Vec<NodeReport>> {
        let roots = g.roots();
        let state = SchedState {
            queue: roots.iter().map(|r| (r.name.clone(), None)).collect(),
            outstanding: roots.len(),
            abort: false,
        };
        let sched = (Mutex::new(state), Condvar::new());
        let reports: Mutex<Vec<NodeReport>> = Mutex::new(Vec::with_capacity(g.stage_count()));
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let workers = self.jobs.min(g.stage_count().max(1));
        std::thread::scope(|scope| {
            for i in 0..workers {
                // named threads give trace spans (and thread dumps) stable
                // per-worker tracks instead of anonymous tids
                std::thread::Builder::new()
                    .name(format!("plan-worker-{i}"))
                    .spawn_scoped(scope, || {
                        self.worker(ctx, g, keys, complete, progress, &sched, &reports, &failure)
                    })
                    .expect("spawning plan worker thread");
            }
        });
        if let Some(e) = failure.into_inner().unwrap_or_else(|p| p.into_inner()) {
            return Err(e);
        }
        Ok(reports.into_inner().unwrap_or_else(|p| p.into_inner()))
    }

    /// One scheduler worker: claim a ready task, run its chain depth-first
    /// (queueing the other live children at forks), repeat until the run
    /// drains or aborts.
    #[allow(clippy::too_many_arguments)]
    fn worker(
        &self,
        ctx: &ExpContext<'rt>,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        complete: &BTreeMap<String, bool>,
        progress: &Progress,
        sched: &(Mutex<SchedState<'rt>>, Condvar),
        reports: &Mutex<Vec<NodeReport>>,
        failure: &Mutex<Option<anyhow::Error>>,
    ) {
        let (lock, cv) = sched;
        'outer: loop {
            // claim the next ready task, or exit once the run has drained
            let task = {
                let mut st = lock.lock().unwrap_or_else(|p| p.into_inner());
                loop {
                    if st.abort || st.outstanding == 0 {
                        break 'outer;
                    }
                    if self.cancelled() {
                        let next = st.queue.front().map(|(n, _)| n.clone()).unwrap_or_default();
                        self.record_interrupt(&mut st, cv, failure, next);
                        break 'outer;
                    }
                    if let Some(t) = st.queue.pop_front() {
                        break t;
                    }
                    // frontier stall: no ready node for this worker — the
                    // span makes scheduler starvation visible in the trace
                    let _stall = crate::span!("sched", "frontier.wait");
                    st = cv.wait(st).unwrap_or_else(|p| p.into_inner());
                }
            };
            let mut cur = Some(task);
            while let Some((name, incoming)) = cur.take() {
                if lock.lock().unwrap_or_else(|p| p.into_inner()).abort {
                    break; // a sibling failed: drop this chain
                }
                if self.cancelled() {
                    let mut st = lock.lock().unwrap_or_else(|p| p.into_inner());
                    self.record_interrupt(&mut st, cv, failure, name);
                    break 'outer;
                }
                let node = g.get(&name).expect("scheduler only queues known nodes");
                match self.step(ctx, g, keys, complete, progress, node, incoming, reports) {
                    Ok(mut children) => {
                        let next = children.pop();
                        let added = children.len();
                        let mut st = lock.lock().unwrap_or_else(|p| p.into_inner());
                        st.outstanding += added + usize::from(next.is_some());
                        st.outstanding -= 1;
                        st.queue.extend(children);
                        if added > 0 || st.outstanding == 0 {
                            cv.notify_all();
                        }
                        drop(st);
                        cur = next;
                    }
                    Err(e) => {
                        let mut f = failure.lock().unwrap_or_else(|p| p.into_inner());
                        if f.is_none() {
                            *f = Some(e);
                        }
                        drop(f);
                        let mut st = lock.lock().unwrap_or_else(|p| p.into_inner());
                        st.abort = true;
                        st.queue.clear();
                        cv.notify_all();
                        break 'outer;
                    }
                }
            }
        }
    }

    /// The cancel flag flipped mid-run: record [`Interrupted`] as the run's
    /// failure (unless a real error already claimed the slot) and abort the
    /// scheduler so every worker drains out.
    fn record_interrupt(
        &self,
        st: &mut SchedState<'rt>,
        cv: &Condvar,
        failure: &Mutex<Option<anyhow::Error>>,
        node: String,
    ) {
        let mut f = failure.lock().unwrap_or_else(|p| p.into_inner());
        if f.is_none() {
            *f = Some(Interrupted { node }.into());
        }
        drop(f);
        st.abort = true;
        st.queue.clear();
        cv.notify_all();
    }

    /// Process one scheduled node: either report its fully-cached subtree,
    /// or execute it inside a kernel-budget share and hand back the live
    /// children (each with its branch snapshot) for scheduling.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        ctx: &ExpContext<'rt>,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        complete: &BTreeMap<String, bool>,
        progress: &Progress,
        node: &Node,
        incoming: Option<Branch<'rt>>,
        reports: &Mutex<Vec<NodeReport>>,
    ) -> Result<Vec<Task<'rt>>> {
        if self.subtree_complete(complete, node) {
            let mut batch = Vec::new();
            self.emit_cached_subtree(g, keys, progress, node, &mut batch)?;
            reports.lock().unwrap_or_else(|p| p.into_inner()).extend(batch);
            return Ok(Vec::new());
        }
        // N in-flight nodes split the kernel budget instead of each fanning
        // over the whole global pool
        let share = threads::acquire_share();
        let (nrep, branch) = share.run(|| self.exec_node(ctx, g, keys, node, incoming))?;
        self.notify_done(progress, &nrep);
        reports.lock().unwrap_or_else(|p| p.into_inner()).push(nrep);

        let mut cached = Vec::new();
        let mut live: Vec<&Node> = Vec::new();
        for child in g.children(&node.name) {
            if self.subtree_complete(complete, child) {
                self.emit_cached_subtree(g, keys, progress, child, &mut cached)?;
            } else {
                live.push(child);
            }
        }
        if !cached.is_empty() {
            reports.lock().unwrap_or_else(|p| p.into_inner()).extend(cached);
        }
        let n_live = live.len();
        let mut branch = Some(branch);
        let mut tasks: Vec<Task<'rt>> = Vec::with_capacity(n_live);
        for (i, child) in live.into_iter().enumerate() {
            let b = if i + 1 < n_live {
                share.run(|| {
                    self.snapshot(
                        ctx,
                        branch.as_ref().expect("branch moves only at the last child"),
                    )
                })?
            } else {
                branch.take().expect("last child takes the branch")
            };
            tasks.push((child.name.clone(), Some(b)));
        }
        Ok(tasks)
    }

    /// Clone a branch at a fork point: weights, masks and any pending
    /// adapters are copied; reconstruction targets are shared by `Arc`.
    fn snapshot(&self, ctx: &ExpContext<'rt>, branch: &Branch<'rt>) -> Result<Branch<'rt>> {
        let mut s = ctx.clone_session(&branch.session)?;
        s.lora = branch.session.lora.clone();
        Ok(Branch { session: s, pre_prune: branch.pre_prune.clone() })
    }

    /// One-pass disk scan: memoize whether every stage in each node's
    /// subtree is complete.  Runs before the walk, so later stage writes
    /// never flip a verdict mid-run (re-checks at exec time go through
    /// `hit()` anyway).
    fn scan_complete(
        &self,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        node: &Node,
        memo: &mut BTreeMap<String, bool>,
    ) -> bool {
        if let Some(&v) = memo.get(&node.name) {
            return v;
        }
        let dir = stage_dir(&self.cache_dir, &keys[&node.name]);
        let own = stage_complete(&dir, node.stage().expect("stage subtree"));
        // scan children unconditionally so every node is memoized — a
        // complete subtree under an incomplete parent still fast-paths
        let kids = g
            .children(&node.name)
            .into_iter()
            .map(|child| self.scan_complete(g, keys, child, memo))
            .collect::<Vec<_>>();
        let v = own && kids.into_iter().all(|c| c);
        memo.insert(node.name.clone(), v);
        v
    }

    /// Is every stage in `node`'s subtree complete on disk (as of the
    /// run-start scan)?  Empty map — `--force` or a capture run — means
    /// "walk everything".
    fn subtree_complete(&self, complete: &BTreeMap<String, bool>, node: &Node) -> bool {
        complete.get(&node.name).copied().unwrap_or(false)
    }

    /// Report a fully-cached subtree from its artifacts alone.
    fn emit_cached_subtree(
        &self,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        progress: &Progress,
        node: &Node,
        out: &mut Vec<NodeReport>,
    ) -> Result<()> {
        let key = keys[&node.name];
        let stage = node.stage().expect("stage subtree");
        let rep = self.cached_report(stage, &key)?;
        let nrep = NodeReport {
            name: node.name.clone(),
            parent: node.parent.clone(),
            seed: self.seed.wrapping_add(node.seed_offset),
            rep,
        };
        self.notify_done(progress, &nrep);
        out.push(nrep);
        for child in g.children(&node.name) {
            self.emit_cached_subtree(g, keys, progress, child, out)?;
        }
        Ok(())
    }

    /// A cache-hit [`StageReport`] assembled purely from disk artifacts.
    fn cached_report(&self, stage: &Stage, key: &Key) -> Result<StageReport> {
        let dir = stage_dir(&self.cache_dir, key);
        let mut rep = StageReport::new(stage.label(), key);
        rep.cache_hit = true;
        match stage {
            Stage::Prune { .. } => rep.sparsity = read_meta_num(&dir, "sparsity"),
            Stage::Retrain { .. } => {
                rep.tps = read_meta_num(&dir, "tps");
                rep.trainable_pct = read_meta_num(&dir, "trainable_pct");
                rep.lr = read_meta_num(&dir, "lr");
            }
            Stage::Reconstruct { .. } => {
                rep.mean_improvement = read_meta_num(&dir, "mean_improvement")
            }
            Stage::Eval { .. } => rep.metrics = Some(read_metrics(&dir.join("metrics.json"))?),
            Stage::Pretrain | Stage::Merge | Stage::Export { .. } => {}
        }
        load_profile(&profile_path(&self.cache_dir, key), &mut rep);
        Ok(rep)
    }

    /// The per-run lock for one stage key.  Two nodes sharing a key (same
    /// chain reached through different branches) serialize here: the first
    /// computes and commits, the second's `hit()` then reads the artifacts.
    fn key_lock(&self, key: &Key) -> Arc<Mutex<()>> {
        let mut map = self.key_locks.lock().unwrap_or_else(|p| p.into_inner());
        map.entry(key.hex()).or_default().clone()
    }

    /// Execute one stage node over its branch, honouring the stage cache.
    fn exec_node(
        &self,
        ctx: &ExpContext<'rt>,
        g: &PlanGraph,
        keys: &BTreeMap<String, Key>,
        node: &Node,
        incoming: Option<Branch<'rt>>,
    ) -> Result<(NodeReport, Branch<'rt>)> {
        let stage = node.stage().expect("walk only visits stage nodes");
        let key = keys[&node.name];
        let dir = stage_dir(&self.cache_dir, &key);
        let eff_seed = self.seed.wrapping_add(node.seed_offset);
        if let Some(h) = &self.hook {
            h(NodeEvent::Started { name: &node.name, key: &key.hex() });
        }
        // in-flight key dedup: a concurrent branch computing the same key
        // finishes (and commits) before this hit-check runs
        let key_lock = self.key_lock(&key);
        let _key_guard = {
            let _wait = crate::span!("lock", "key.wait {}", &key.hex()[..10]);
            key_lock.lock().unwrap_or_else(|p| p.into_inner())
        };
        let _node_span = crate::span!("node", "{}", node.name)
            .arg("stage", stage.label())
            .arg("key", &key.hex()[..10]);
        let t0 = Instant::now();
        let snap0 = Registry::global().snapshot();
        let mut rep = StageReport::new(stage.label(), &key);
        // cache-miss artifacts stream into a private staging dir and land
        // via one atomic rename — a killed or racing run never leaves a
        // partial dir that later scans as complete
        let tmp = tmp_stage_dir(&self.cache_dir, &key);

        let branch = match stage {
            Stage::Pretrain => {
                rep.cache_hit = !self.force && dir.join("meta.json").is_file();
                // dense_session loads the shared checkpoint when present,
                // so even a cache-miss marker costs no training steps if
                // an earlier run (or sweep) already converged this config
                let session = ctx.dense_session(eff_seed)?;
                if !rep.cache_hit {
                    self.write_meta(&tmp, stage, vec![])?;
                }
                Branch { session, pre_prune: None }
            }
            _ => {
                let mut branch =
                    incoming.expect("validated graph: non-root stages inherit a session");
                match stage {
                    Stage::Pretrain => unreachable!("handled above"),
                    Stage::Prune { criterion, pattern } => {
                        let s = &mut branch.session;
                        // snapshot the reconstruction targets from the
                        // incoming weights — correct on both the hit and
                        // miss path, and only when a descendant needs them
                        if g.subtree_reconstructs(&node.name) {
                            branch.pre_prune = Some(Arc::new(
                                s.mm.prunable
                                    .iter()
                                    .map(|n| (n.clone(), s.params.get(n).clone()))
                                    .collect(),
                            ));
                        }
                        if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                            rep.cache_hit = true;
                            self.load_state(s, &dir)?;
                            rep.sparsity = read_meta_num(&dir, "sparsity");
                        } else {
                            let grams = if criterion.needs_calibration() {
                                Some(s.calibrate()?)
                            } else {
                                None
                            };
                            s.prune(*criterion, *pattern, grams.as_ref())?;
                            let sparsity = s.masks.sparsity();
                            rep.sparsity = Some(sparsity);
                            self.save_state(s, &tmp)?;
                            self.write_meta(&tmp, stage, vec![("sparsity", Json::Num(sparsity))])?;
                        }
                    }
                    Stage::Retrain { mode, steps, lr } => {
                        let steps = steps.unwrap_or(self.cfg.retrain_steps);
                        let mut needs = vec!["state.ptns", "masks.ptns"];
                        if mode.is_lora() {
                            needs.push("lora.ptns");
                        }
                        needs.push("meta.json");
                        if self.hit(&dir, &needs) {
                            rep.cache_hit = true;
                            let s = &mut branch.session;
                            self.load_state(s, &dir)?;
                            s.lora = if mode.is_lora() {
                                Some((*mode, load_lora(&s.mm, &dir.join("lora.ptns"))?))
                            } else {
                                None
                            };
                            s.last_tps = read_meta_num(&dir, "tps").unwrap_or(0.0);
                            rep.tps = Some(s.last_tps);
                            rep.trainable_pct = read_meta_num(&dir, "trainable_pct");
                            rep.lr = read_meta_num(&dir, "lr");
                        } else {
                            // unpinned lr → the legacy grid tuning (no-op for
                            // the single-entry grids the shipped profiles use)
                            let lr = match lr {
                                Some(l) => *l,
                                None => self.tuned_lr(ctx, &branch.session, *mode, steps)?,
                            };
                            // fresh clone, exactly like the legacy retrain
                            // path; the incoming session drops at assignment
                            branch.session = ctx.clone_session(&branch.session)?;
                            let s = &mut branch.session;
                            s.retrain(*mode, steps, lr)?;
                            let pct = 100.0 * s.mm.trainable_count(mode.trainable_key()) as f64
                                / s.mm.total_params() as f64;
                            rep.tps = Some(s.last_tps);
                            rep.trainable_pct = Some(pct);
                            rep.lr = Some(lr);
                            self.save_state(s, &tmp)?;
                            if let Some((_, lora)) = &s.lora {
                                io::save(&tmp.join("lora.ptns"), &lora.tensors)
                                    .context("saving adapters")?;
                            }
                            self.write_meta(
                                &tmp,
                                stage,
                                vec![
                                    ("tps", Json::Num(s.last_tps)),
                                    ("trainable_pct", Json::Num(pct)),
                                    ("lr", Json::Num(lr)),
                                ],
                            )?;
                        }
                    }
                    Stage::Reconstruct { mode, steps, lr } => {
                        let steps = steps.unwrap_or(self.cfg.recon_steps);
                        let lr = lr.unwrap_or(self.cfg.recon_lr);
                        if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                            rep.cache_hit = true;
                            self.load_state(&mut branch.session, &dir)?;
                            rep.mean_improvement = read_meta_num(&dir, "mean_improvement");
                        } else {
                            let dense = branch
                                .pre_prune
                                .clone()
                                .expect("validated graph: reconstruct follows a prune");
                            branch.session = ctx.clone_session(&branch.session)?;
                            let s = &mut branch.session;
                            let target = s.masks.clone();
                            let report =
                                reconstruct::reconstruct(s, &target, &dense, *mode, steps, lr)?;
                            rep.mean_improvement = Some(report.mean_improvement());
                            self.save_state(s, &tmp)?;
                            self.write_meta(
                                &tmp,
                                stage,
                                vec![("mean_improvement", Json::Num(report.mean_improvement()))],
                            )?;
                        }
                    }
                    Stage::Merge => {
                        let s = &mut branch.session;
                        if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                            rep.cache_hit = true;
                            self.load_state(s, &dir)?;
                            s.lora = None;
                        } else {
                            s.merge_adapters()?;
                            self.save_state(s, &tmp)?;
                            self.write_meta(&tmp, stage, vec![])?;
                        }
                    }
                    Stage::Eval { tasks } => {
                        if self.hit(&dir, &["metrics.json"]) {
                            rep.cache_hit = true;
                            rep.metrics = Some(read_metrics(&dir.join("metrics.json"))?);
                        } else {
                            let s = &mut branch.session;
                            let ppl = s.eval_ppl_test()?;
                            let (acc, per_task) = if *tasks {
                                let tr = s.eval_tasks()?;
                                (
                                    crate::eval::mean_accuracy(&tr),
                                    tr.into_iter()
                                        .map(|t| (t.name, t.accuracy))
                                        .collect::<Vec<_>>(),
                                )
                            } else {
                                (f64::NAN, Vec::new())
                            };
                            let m = EvalMetrics {
                                ppl: ppl.ppl,
                                loss: ppl.loss,
                                acc,
                                per_task,
                                sparsity: s.params.weight_sparsity(&s.mm),
                            };
                            write_metrics(&tmp.join("metrics.json"), &m)?;
                            rep.metrics = Some(m);
                        }
                    }
                    Stage::Export { path } => {
                        let target = Path::new(path);
                        let recorded = read_meta_str(&dir, "content_fnv");
                        if !self.force
                            && recorded.is_some()
                            && recorded == file_fnv(target)
                        {
                            // byte-identical checkpoint already on disk —
                            // idempotent skip, reported as a cache hit
                            rep.cache_hit = true;
                        } else {
                            branch.session.save(target)?;
                            let fingerprint =
                                file_fnv(target).context("hashing exported checkpoint")?;
                            self.write_meta(
                                &tmp,
                                stage,
                                vec![("content_fnv", Json::Str(fingerprint))],
                            )?;
                        }
                    }
                }
                branch
            }
        };

        if !rep.cache_hit {
            commit_stage_dir(&tmp, &dir)?;
        }
        rep.wall_s = t0.elapsed().as_secs_f64();
        if rep.cache_hit {
            crate::count!("plan.cache.hit");
            load_profile(&profile_path(&self.cache_dir, &key), &mut rep);
        } else {
            crate::count!("plan.cache.miss");
            rep.counters = Registry::global().snapshot().since(&snap0).counters;
            rep.computed_wall_s = Some(rep.wall_s);
            write_profile(&profile_path(&self.cache_dir, &key), &rep);
        }
        let nrep = NodeReport {
            name: node.name.clone(),
            parent: node.parent.clone(),
            seed: eff_seed,
            rep,
        };
        Ok((nrep, branch))
    }

    /// Reduce every aggregate node over the eval metrics its targets
    /// produced this run.
    fn reduce_aggregates(
        &self,
        g: &PlanGraph,
        reports: &[NodeReport],
    ) -> Result<Vec<AggregateRow>> {
        let mut rows = Vec::new();
        for node in &g.nodes {
            let NodeKind::Aggregate { over } = &node.kind else {
                continue;
            };
            let mut ppls = Vec::with_capacity(over.len());
            let mut accs = Vec::with_capacity(over.len());
            let mut sparsities = Vec::with_capacity(over.len());
            for target in over {
                let metrics = reports
                    .iter()
                    .find(|r| &r.name == target)
                    .and_then(|r| r.rep.metrics.as_ref())
                    .with_context(|| {
                        format!("aggregate {:?}: no eval metrics for node {target:?}", node.name)
                    })?;
                ppls.push(metrics.ppl);
                accs.push(metrics.acc);
                sparsities.push(metrics.sparsity);
            }
            rows.push(AggregateRow {
                name: node.name.clone(),
                over: over.clone(),
                ppl: mean_std(&ppls),
                acc: mean_std(&accs),
                sparsity: mean_std(&sparsities),
            });
        }
        Ok(rows)
    }

    /// The legacy lr-grid scan (mirrors `ExpContext::retrain_tuned`): train
    /// once per grid entry, evaluate test ppl merged (standard LoRA stays
    /// unmerged), return the winning lr.  Single-entry grids — every shipped
    /// profile — skip the scan, so `Retrain { lr: None }` costs nothing
    /// extra there; multi-entry grids pay one extra retrain of the winner
    /// (the stage then re-trains at that lr so its artifact is uniformly
    /// *unmerged*, keeping the explicit `merge` stage meaningful).
    fn tuned_lr(
        &self,
        ctx: &ExpContext<'rt>,
        base: &Session<'rt>,
        mode: Mode,
        steps: u64,
    ) -> Result<f64> {
        if self.cfg.lr_grid.len() == 1 {
            return Ok(self.cfg.lr_grid[0]);
        }
        let mut best: Option<(f64, f64)> = None; // (test ppl, lr)
        for &lr in &self.cfg.lr_grid {
            let mut s = ctx.clone_session(base)?;
            s.retrain(mode, steps, lr)?;
            if mode != Mode::Lora {
                s.merge_adapters()?;
            }
            let ppl = s.eval_ppl_test()?.ppl;
            if best.map(|(b, _)| ppl < b).unwrap_or(true) {
                best = Some((ppl, lr));
            }
        }
        Ok(best.expect("non-empty lr grid").1)
    }

    // ------------------------------------------------------------------
    // Artifact plumbing.
    // ------------------------------------------------------------------

    fn hit(&self, dir: &Path, needs: &[&str]) -> bool {
        !self.force && needs.iter().all(|f| dir.join(f).is_file())
    }

    fn save_state(&self, s: &Session, dir: &Path) -> Result<()> {
        io::save(&dir.join("state.ptns"), s.params.map()).context("saving stage weights")?;
        io::save(&dir.join("masks.ptns"), &s.masks.masks).context("saving stage masks")?;
        Ok(())
    }

    fn load_state(&self, s: &mut Session, dir: &Path) -> Result<()> {
        s.params = ParamStore::load(&s.mm, &dir.join("state.ptns"))?;
        s.masks = load_masks(&s.mm, &dir.join("masks.ptns"))?;
        // cached stage artifacts bypass prune()/merge(): recompress here
        s.refresh_sparse();
        Ok(())
    }

    /// Write `meta.json` — the completion marker, so it must come last
    /// within the staging dir (the dir itself then lands atomically).
    fn write_meta(&self, dir: &Path, stage: &Stage, extra: Vec<(&str, Json)>) -> Result<()> {
        let mut pairs = vec![("stage", stage.to_json())];
        pairs.extend(extra);
        write_json(&dir.join("meta.json"), &Json::obj(pairs))
    }
}

/// Canonical topological order of the stage nodes: roots in declaration
/// order, children depth-first in insertion order.  This is the report
/// order whatever schedule actually executed the nodes.
fn dfs_order(g: &PlanGraph) -> BTreeMap<String, usize> {
    fn visit(g: &PlanGraph, node: &Node, out: &mut BTreeMap<String, usize>) {
        let idx = out.len();
        out.insert(node.name.clone(), idx);
        for child in g.children(&node.name) {
            visit(g, child, out);
        }
    }
    let mut out = BTreeMap::new();
    for root in g.roots() {
        visit(g, root, &mut out);
    }
    out
}

/// A private staging dir for one stage execution, unique per (process,
/// attempt) so concurrent writers never collide: `plan/.tmp-<key>-<pid>-<n>`.
fn tmp_stage_dir(cache_dir: &Path, key: &Key) -> PathBuf {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let unique = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    cache_dir
        .join("plan")
        .join(format!(".tmp-{}-{}-{unique}", key.hex(), std::process::id()))
}

/// Land a completed staging dir at its content-addressed path in one
/// rename.  A pre-existing dir (stale partial, `--force` recompute) is
/// cleared first; losing a cross-process race is fine — the winner wrote
/// the same content-addressed artifacts, so the loser's copy is dropped.
fn commit_stage_dir(tmp: &Path, dst: &Path) -> Result<()> {
    if !tmp.is_dir() {
        // stage produced no local artifacts (defensive: meta is always
        // written, so this should not happen)
        return Ok(());
    }
    if dst.is_dir() {
        std::fs::remove_dir_all(dst)
            .with_context(|| format!("clearing stale stage dir {dst:?}"))?;
    }
    match std::fs::rename(tmp, dst) {
        Ok(()) => Ok(()),
        Err(_) if dst.is_dir() => {
            std::fs::remove_dir_all(tmp).ok();
            Ok(())
        }
        Err(e) => {
            Err(e).with_context(|| format!("committing stage dir {tmp:?} -> {dst:?}"))
        }
    }
}

fn load_masks(mm: &ModelManifest, path: &Path) -> Result<MaskSet> {
    let loaded = io::load(path)?;
    let mut ms = MaskSet::default();
    for n in &mm.prunable {
        let t = loaded
            .get(n)
            .with_context(|| format!("mask artifact {path:?} missing {n:?}"))?;
        ms.set(n, t.clone());
    }
    Ok(ms)
}

fn load_lora(mm: &ModelManifest, path: &Path) -> Result<LoraState> {
    let loaded = io::load(path)?;
    let mut st = LoraState::default();
    for (name, shape) in &mm.adapters {
        let t = loaded
            .get(name)
            .with_context(|| format!("adapter artifact {path:?} missing {name:?}"))?;
        anyhow::ensure!(
            t.shape() == &shape[..],
            "adapter {name:?} shape {:?} vs manifest {:?}",
            t.shape(),
            shape
        );
        st.tensors.insert(name.clone(), t.clone());
    }
    Ok(st)
}

/// NaN/inf-safe number: serialized as null, read back as the given default.
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn write_metrics(path: &Path, m: &EvalMetrics) -> Result<()> {
    let per_task = Json::Arr(
        m.per_task
            .iter()
            .map(|(name, acc)| {
                Json::obj(vec![("task", Json::Str(name.clone())), ("acc", num_or_null(*acc))])
            })
            .collect(),
    );
    write_json(
        path,
        &Json::obj(vec![
            ("ppl", num_or_null(m.ppl)),
            ("loss", num_or_null(m.loss)),
            ("acc", num_or_null(m.acc)),
            ("per_task", per_task),
            ("sparsity", num_or_null(m.sparsity)),
        ]),
    )
}

fn read_metrics(path: &Path) -> Result<EvalMetrics> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
    let num = |key: &str, default: f64| j.get(key).and_then(Json::as_f64).unwrap_or(default);
    let per_task = j
        .get("per_task")
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let name = e.get("task")?.as_str()?.to_string();
                    let acc = e.get("acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    Some((name, acc))
                })
                .collect()
        })
        .unwrap_or_default();
    Ok(EvalMetrics {
        ppl: num("ppl", f64::INFINITY),
        loss: num("loss", f64::INFINITY),
        acc: num("acc", f64::NAN),
        per_task,
        sparsity: num("sparsity", 0.0),
    })
}

/// Profile sidecar path for one stage key: `plan/<key>.prof.json`, a file
/// *next to* — never inside — the stage dir.  Stage dirs must stay
/// bitwise-identical across runs and schedules (pinned by the graph parity
/// tests), so volatile observations (wall clock, counter deltas) live in
/// this sidecar instead.  `gc` only considers directories, so sidecars are
/// never mistaken for unreachable stage dirs.
fn profile_path(cache_dir: &Path, key: &Key) -> PathBuf {
    cache_dir.join("plan").join(format!("{}.prof.json", key.hex()))
}

/// Record a freshly computed node's wall clock + counter deltas.  Best
/// effort: profile data is observability, never semantics, so write errors
/// are swallowed.
fn write_profile(path: &Path, rep: &StageReport) {
    let counters: Vec<(&str, Json)> =
        rep.counters.iter().map(|(k, &v)| (k.as_str(), Json::Num(v as f64))).collect();
    let j = Json::obj(vec![
        ("stage", Json::Str(rep.label.clone())),
        ("wall_s", num_or_null(rep.wall_s)),
        ("counters", Json::obj(counters)),
    ]);
    let _ = write_json(path, &j);
}

/// Load recorded wall clock + counters into a cache-hit report (no-op when
/// the stage predates profiling or the sidecar is unreadable).
fn load_profile(path: &Path, rep: &mut StageReport) {
    if let Some((wall_s, counters)) = parse_profile(path) {
        rep.computed_wall_s = wall_s;
        rep.counters = counters;
    }
}

/// Recorded `(wall_s, counter deltas)` for one stage key, if a profile
/// sidecar exists — `plan show --timings` reads these without re-running.
pub fn recorded_profile(
    cache_dir: &Path,
    key: &Key,
) -> Option<(Option<f64>, BTreeMap<String, u64>)> {
    parse_profile(&profile_path(cache_dir, key))
}

fn parse_profile(path: &Path) -> Option<(Option<f64>, BTreeMap<String, u64>)> {
    let j = Json::parse(&std::fs::read_to_string(path).ok()?).ok()?;
    let wall_s = j.get("wall_s").and_then(Json::as_f64);
    let counters = j
        .get("counters")
        .and_then(Json::as_obj)
        .map(|map| {
            map.iter()
                .filter_map(|(k, v)| v.as_f64().map(|n| (k.clone(), n as u64)))
                .collect()
        })
        .unwrap_or_default();
    Some((wall_s, counters))
}

fn read_meta_num(dir: &Path, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(dir.join("meta.json")).ok()?;
    Json::parse(&text).ok()?.get(key).and_then(Json::as_f64)
}

fn read_meta_str(dir: &Path, key: &str) -> Option<String> {
    let text = std::fs::read_to_string(dir.join("meta.json")).ok()?;
    Json::parse(&text)
        .ok()?
        .get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
}

/// Atomic-enough JSON write: temp file in the target directory, then rename.
/// The temp name is unique per (process, write) — like `io::save` — so
/// concurrent executors racing on one stage key never truncate each other's
/// in-flight marker.
fn write_json(path: &Path, j: &Json) -> Result<()> {
    let dir = path.parent().context("json path has no parent")?;
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let unique = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp{}-{unique}", std::process::id()));
    std::fs::write(&tmp, j.to_string()).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}
