//! The coordinator: PERP's prune→retrain / prune→reconstruct pipelines.
//!
//! [`session::Session`] owns all mutable state (params, masks, adapters,
//! optimizer buffers, data) and exposes the pipeline verbs:
//!
//! * `pretrain`          — converge the dense model (full-FT steps, dense masks)
//! * `calibrate`         — accumulate per-layer Grams on calibration data
//! * `prune`             — magnitude / wanda / sparsegpt × unstructured / N:M
//! * `retrain`           — any PERP mode (subsets, LoRA variants)
//! * `merge_adapters`    — fold LoRA state back, verifying sparsity
//! * `reconstruct`       — sequential layer-wise Eq. 1 optimisation
//! * `eval_ppl` / `eval_tasks`
//!
//! [`sweep`] builds every paper table/figure from these verbs.

pub mod reconstruct;
pub mod session;
pub mod sweep;

pub use session::Session;
