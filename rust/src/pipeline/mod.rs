//! Declarative pipeline plans: compose PERP's verbs instead of hard-wiring
//! one sequence per subcommand — and fan them out as DAGs when cells share
//! a prefix.
//!
//! * [`plan`] — the typed [`Stage`] enum and the linear [`Plan`] container
//!   with a builder API, JSON (de)serialization over [`crate::util::json`]
//!   and structural validation (`merge` needs a pending LoRA retrain,
//!   `retrain` needs masks, ...).
//! * [`graph`] — [`PlanGraph`]: named stage nodes with parent edges, fluent
//!   fan-out combinators ([`GraphBuilder`]: `fork_over`, `fork_sparsities`,
//!   `grid`, `replicate_seeds`) and [`Aggregate`](graph::NodeKind::Aggregate)
//!   nodes reducing leaf evals into mean±std rows.  A linear `Plan` is a
//!   single-path graph ([`Plan::to_graph`]); keys are root-path chains, so
//!   both forms share one cache.
//! * [`parse`] — the inline `--stages` grammar:
//!   `"prune(wanda,0.5)|retrain(masklora,100)|merge|eval"`, plus the
//!   fan-out forms `fork[a|b;c|d]`, `seeds(n)` and `agg`.
//! * [`cachekey`] — content addressing: every stage is keyed by an FNV-1a
//!   chain over (model, experiment config, seed, all upstream stage specs),
//!   so two plans sharing a prefix share its artifacts.
//! * [`executor`] — the ready-set scheduler: walks a [`PlanGraph`] over
//!   [`crate::coordinator::Session`]s, executing every shared prefix once
//!   per run (session snapshots at fork points) and persisting per-stage
//!   artifacts (`state.ptns`, `masks.ptns`, adapters, `meta.json`) under
//!   `<cache>/plan/<key>/` via temp-dir + atomic rename.  With `--jobs N`
//!   independent subtrees execute concurrently on a worker pool that
//!   splits the kernel thread budget (see [`crate::util::threads`]) —
//!   reports, artifacts and metrics stay bitwise-identical to the serial
//!   walk.  Re-running a plan loads completed stages instead of
//!   recomputing them — fully-cached subtrees never even materialise a
//!   session; `--force` ignores the stage cache (the keyed dense pretrain
//!   checkpoint is still reused — it is deterministic in the key inputs).
//!
//! The CLI subcommands (`repro pretrain/prune/retrain/reconstruct/eval`) are
//! thin shims over 1–3 distinctive stages each, `repro run` executes
//! arbitrary plan or graph files, and the sweep registry generates plan
//! graphs for its tables — one execution path for everything.

pub mod cachekey;
pub mod executor;
pub mod graph;
pub mod parse;
pub mod plan;

pub use executor::{
    AggregateRow, EvalMetrics, Executor, GraphReport, Interrupted, NodeEvent, NodeHook,
    NodeReport, RunReport, StageReport,
};
pub use graph::{GraphBuilder, Node, NodeKind, PlanGraph, PlanOrGraph};
pub use plan::{Plan, Stage};
