//! Evaluation: perplexity (WikiText analogue) and the zero-shot suite
//! (EleutherAI-harness analogue).
//!
//! Both run purely through compiled executables — `eval_loss` aggregates
//! exact token-level NLL sums; `score` returns per-sequence option log-probs
//! for likelihood ranking.

use anyhow::Result;

use crate::data::tasks::Task;
use crate::data::{Batcher, Corpus, Tokenizer};
use crate::model::ParamStore;
use crate::peft::LoraState;
use crate::pruning::MaskSet;
use crate::runtime::{Backend, Feed, ModelManifest};
use crate::tensor::sparse::SparseStore;
use crate::tensor::Tensor;

/// Build the base feed shared by every executable: all params + masks.
pub fn base_feed<'a>(ps: &'a ParamStore, masks: &'a MaskSet) -> Feed<'a> {
    let mut f = Feed::new();
    for (n, t) in ps.map() {
        f = f.tensor(&format!("p::{n}"), t);
    }
    for (n, t) in &masks.masks {
        f = f.tensor(&format!("m::{n}"), t);
    }
    f
}

/// [`base_feed`] plus the cached sparse-layout side channel, when the
/// caller has one (the coordinator's sessions always do).
pub fn model_feed<'a>(
    ps: &'a ParamStore,
    masks: &'a MaskSet,
    sparse: Option<&'a SparseStore>,
) -> Feed<'a> {
    let mut f = base_feed(ps, masks);
    if let Some(sp) = sparse {
        f = f.sparse(sp);
    }
    f
}

/// Extend a feed with adapter tensors under the aot naming (a::/b::).
pub fn adapter_feed<'a>(mut f: Feed<'a>, lora: &'a LoraState) -> Feed<'a> {
    for (name, t) in &lora.tensors {
        let (lin, tag) = crate::coordinator::session::split_adapter_name(name);
        f = f.owned_key(format!("{tag}::{lin}"), t);
    }
    f
}

#[derive(Debug, Clone)]
pub struct PplResult {
    pub loss: f64,
    pub ppl: f64,
    pub tokens: f64,
}

/// Exact perplexity over (up to `max_batches` of) a batcher's windows.
pub fn perplexity(
    rt: &dyn Backend,
    mm: &ModelManifest,
    ps: &ParamStore,
    masks: &MaskSet,
    sparse: Option<&SparseStore>,
    batcher: &Batcher,
    max_batches: usize,
) -> Result<PplResult> {
    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let shape = [b, s];
    let n = batcher.n_eval_batches(b).min(max_batches).max(1);
    let (mut loss_sum, mut count) = (0.0f64, 0.0f64);
    for i in 0..n {
        let tokens = batcher.eval_batch(b, i);
        let feed = model_feed(ps, masks, sparse).ints("tokens", &shape, &tokens);
        let out = rt.run(&mm.cfg.name, "eval_loss", &feed)?;
        loss_sum += out.scalar("loss_sum") as f64;
        count += out.scalar("count") as f64;
    }
    let loss = loss_sum / count.max(1.0);
    Ok(PplResult { loss, ppl: loss.exp(), tokens: count })
}

/// Perplexity with standard-LoRA adapters active (unmerged).
#[allow(clippy::too_many_arguments)]
pub fn perplexity_lora(
    rt: &dyn Backend,
    mm: &ModelManifest,
    ps: &ParamStore,
    masks: &MaskSet,
    sparse: Option<&SparseStore>,
    lora: &LoraState,
    batcher: &Batcher,
    max_batches: usize,
) -> Result<PplResult> {
    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let shape = [b, s];
    let n = batcher.n_eval_batches(b).min(max_batches).max(1);
    let (mut loss_sum, mut count) = (0.0f64, 0.0f64);
    for i in 0..n {
        let tokens = batcher.eval_batch(b, i);
        let feed =
            adapter_feed(model_feed(ps, masks, sparse), lora).ints("tokens", &shape, &tokens);
        let out = rt.run(&mm.cfg.name, "eval_loss_lora", &feed)?;
        loss_sum += out.scalar("loss_sum") as f64;
        count += out.scalar("count") as f64;
    }
    let loss = loss_sum / count.max(1.0);
    Ok(PplResult { loss, ppl: loss.exp(), tokens: count })
}

/// Mean ± sample standard deviation over `n` observations — the multi-seed
/// aggregation unit (plan-graph `Aggregate` nodes reduce leaf eval metrics
/// into these; sweep tables print them as `m±s` cells).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeanStd {
    pub mean: f64,
    /// sample std (n−1 denominator); 0 when n < 2.  NaN inputs propagate —
    /// a ppl-only eval's NaN accuracy stays visibly NaN instead of being
    /// silently dropped from the average.
    pub std: f64,
    pub n: usize,
}

impl MeanStd {
    /// `12.34±0.56`, collapsing to the bare mean for single observations
    /// and `-` for NaN (matching the sweep tables' missing-cell marker).
    pub fn display(&self, decimals: usize) -> String {
        if self.mean.is_nan() {
            return "-".to_string();
        }
        if self.n < 2 {
            format!("{:.*}", decimals, self.mean)
        } else {
            format!("{:.*}±{:.*}", decimals, self.mean, decimals, self.std)
        }
    }
}

impl std::fmt::Display for MeanStd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.display(2))
    }
}

pub fn mean_std(xs: &[f64]) -> MeanStd {
    let n = xs.len();
    if n == 0 {
        return MeanStd { mean: f64::NAN, std: f64::NAN, n: 0 };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let std = if n < 2 {
        0.0
    } else {
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    };
    MeanStd { mean, std, n }
}

#[derive(Debug, Clone)]
pub struct TaskResult {
    pub name: String,
    pub accuracy: f64,
    pub items: usize,
}

pub fn mean_accuracy(results: &[TaskResult]) -> f64 {
    results.iter().map(|r| r.accuracy).sum::<f64>() / results.len().max(1) as f64
}

/// Token-id lookup for corpus word ids (the tasks are generated as word ids).
pub fn word_token_lut(corpus: &Corpus, tok: &Tokenizer) -> Vec<i32> {
    corpus
        .lexicon
        .iter()
        .map(|w| {
            let ids = tok.encode(w);
            ids.first().copied().unwrap_or(crate::data::tokenizer::UNK)
        })
        .collect()
}

/// Run the full zero-shot suite; per-task accuracy via length-normalised
/// likelihood ranking, batched through the `score` executable.
#[allow(clippy::too_many_arguments)]
pub fn zero_shot(
    rt: &dyn Backend,
    mm: &ModelManifest,
    ps: &ParamStore,
    masks: &MaskSet,
    sparse: Option<&SparseStore>,
    lora: Option<&LoraState>,
    tasks: &[Task],
    lut: &[i32],
) -> Result<Vec<TaskResult>> {
    let exec = if lora.is_some() { "score_lora" } else { "score" };
    let b = mm.cfg.eval_batch;
    let s = mm.cfg.seq_len;
    let shape = [b, s];

    let mut results = Vec::with_capacity(tasks.len());
    for task in tasks {
        // flatten (item, option) pairs into scoring rows
        let mut rows_tokens: Vec<i32> = Vec::new();
        let mut rows_tmask: Vec<f32> = Vec::new();
        let mut row_meta: Vec<(usize, usize)> = Vec::new(); // (item, option)
        for (ii, item) in task.items.iter().enumerate() {
            for (oi, opt) in item.options.iter().enumerate() {
                let (t, m) = render_row(&item.context, opt, lut, s);
                rows_tokens.extend(t);
                rows_tmask.extend(m);
                row_meta.push((ii, oi));
            }
        }
        // pad the row count to a batch multiple
        while row_meta.len() % b != 0 {
            rows_tokens.extend(vec![crate::data::tokenizer::PAD; s]);
            rows_tmask.extend(vec![0.0; s]);
            row_meta.push((usize::MAX, 0));
        }

        let mut scores: Vec<Vec<f64>> = task
            .items
            .iter()
            .map(|it| vec![0.0; it.options.len()])
            .collect();
        for chunk in 0..row_meta.len() / b {
            let t = &rows_tokens[chunk * b * s..(chunk + 1) * b * s];
            let mvals = &rows_tmask[chunk * b * s..(chunk + 1) * b * s];
            let tmask = Tensor::new(&[b, s], mvals.to_vec());
            let mut feed = model_feed(ps, masks, sparse)
                .ints("tokens", &shape, t)
                .owned("tmask", tmask);
            if let Some(l) = lora {
                feed = adapter_feed(feed, l);
            }
            let out = rt.run(&mm.cfg.name, exec, &feed)?;
            let sc = out.get("scores");
            let ct = out.get("counts");
            for r in 0..b {
                let (ii, oi) = row_meta[chunk * b + r];
                if ii == usize::MAX {
                    continue;
                }
                let cnt = ct.data()[r].max(1.0);
                scores[ii][oi] = sc.data()[r] as f64 / cnt as f64;
            }
        }

        let correct = task
            .items
            .iter()
            .zip(&scores)
            .filter(|(item, sc)| {
                let best = sc
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                best == item.correct
            })
            .count();
        results.push(TaskResult {
            name: task.name.clone(),
            accuracy: correct as f64 / task.items.len().max(1) as f64,
            items: task.items.len(),
        });
    }
    Ok(results)
}

/// Lay out one scoring row: [BOS] ctx option PAD...; tmask = 1 on option
/// token positions (truncating from the left if the row overflows).
fn render_row(context: &[u32], option: &[u32], lut: &[i32], seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    use crate::data::tokenizer::{BOS, PAD};
    let mut toks = vec![BOS];
    toks.extend(context.iter().map(|&w| lut[w as usize]));
    let opt_start = toks.len();
    toks.extend(option.iter().map(|&w| lut[w as usize]));
    let mut tmask = vec![0.0f32; toks.len()];
    for x in tmask[opt_start..].iter_mut() {
        *x = 1.0;
    }
    if toks.len() > seq_len {
        let cut = toks.len() - seq_len;
        toks.drain(..cut);
        tmask.drain(..cut);
    }
    while toks.len() < seq_len {
        toks.push(PAD);
        tmask.push(0.0);
    }
    (toks, tmask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_row_masks_only_option() {
        let lut: Vec<i32> = (0..10).map(|i| i + 4).collect();
        let (t, m) = render_row(&[1, 2], &[3, 4, 5], &lut, 10);
        assert_eq!(t.len(), 10);
        assert_eq!(m.len(), 10);
        assert_eq!(t[0], crate::data::tokenizer::BOS);
        assert_eq!(&m[..3], &[0.0, 0.0, 0.0]);
        assert_eq!(&m[3..6], &[1.0, 1.0, 1.0]);
        assert_eq!(&m[6..], &[0.0; 4]);
        assert_eq!(t[9], crate::data::tokenizer::PAD);
    }

    #[test]
    fn render_row_truncates_left() {
        let lut: Vec<i32> = (0..50).map(|i| i + 4).collect();
        let ctx: Vec<u32> = (0..20).collect();
        let opt: Vec<u32> = (20..25).collect();
        let (t, m) = render_row(&ctx, &opt, &lut, 12);
        assert_eq!(t.len(), 12);
        // option tokens (last 5) all still masked
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 5);
        assert_eq!(&m[7..], &[1.0; 5]);
    }

    #[test]
    fn mean_accuracy_math() {
        let rs = vec![
            TaskResult { name: "a".into(), accuracy: 0.5, items: 10 },
            TaskResult { name: "b".into(), accuracy: 1.0, items: 10 },
        ];
        assert_eq!(mean_accuracy(&rs), 0.75);
    }

    #[test]
    fn mean_std_math_and_display() {
        let m = mean_std(&[1.0, 2.0, 3.0]);
        assert_eq!(m.mean, 2.0);
        assert!((m.std - 1.0).abs() < 1e-12);
        assert_eq!(m.n, 3);
        assert_eq!(m.display(2), "2.00±1.00");

        let single = mean_std(&[4.25]);
        assert_eq!(single.std, 0.0);
        assert_eq!(single.display(2), "4.25");

        assert!(mean_std(&[]).mean.is_nan());
        assert_eq!(mean_std(&[]).display(2), "-");
        // NaN propagates instead of being dropped
        assert!(mean_std(&[1.0, f64::NAN]).mean.is_nan());
    }
}
