//! Thread-local f32 buffer pool — tape reuse across training/decode steps.
//!
//! Every native-backend step allocates the same set of large activation
//! buffers (logits, attention probabilities, per-linear effective weights),
//! uses them once, and frees them.  This pool recycles those allocations on
//! the thread that made them: kernels request scratch via [`zeroed`], and
//! the backend returns consumed tapes via [`recycle`]/[`give`] after each
//! step.  Buffers are keyed by exact length, so a steady-state training or
//! decode loop hits the pool for every allocation after the first step.
//!
//! The pool is best-effort and invisible to semantics: a buffer that is
//! never recycled is simply freed by the allocator, and recycled buffers
//! are re-zeroed before reuse.  `PERP_TAPE_POOL=0` (or
//! [`set_enabled`]`(false)`) disables reuse — the A/B knob behind the
//! `runtime_micro` allocator-churn comparison.

use std::cell::RefCell;
use std::collections::HashMap;

use super::Tensor;

/// Recycled buffers kept per exact length.
const PER_LEN_CAP: usize = 8;
/// Total bytes the pool may hold per thread.
const BYTES_CAP: usize = 1 << 28; // 256 MiB

#[derive(Default)]
struct Pool {
    by_len: HashMap<usize, Vec<Vec<f32>>>,
    bytes: usize,
    hits: u64,
    misses: u64,
    /// Lazily resolved from `PERP_TAPE_POOL` (default on).
    enabled: Option<bool>,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

fn enabled(p: &mut Pool) -> bool {
    *p.enabled.get_or_insert_with(|| {
        !matches!(std::env::var("PERP_TAPE_POOL").as_deref(), Ok("0") | Ok("off"))
    })
}

/// A zero-filled f32 buffer of exactly `len`, reusing a recycled allocation
/// from this thread's pool when one is available.
pub fn zeroed(len: usize) -> Vec<f32> {
    if len == 0 {
        return Vec::new();
    }
    POOL.with(|cell| {
        let mut p = cell.borrow_mut();
        let pm = &mut *p;
        if enabled(pm) {
            if let Some(mut v) = pm.by_len.get_mut(&len).and_then(|l| l.pop()) {
                pm.bytes -= 4 * len;
                pm.hits += 1;
                crate::count!("pool.tape.hit");
                v.iter_mut().for_each(|x| *x = 0.0);
                return v;
            }
        }
        pm.misses += 1;
        crate::count!("pool.tape.miss");
        vec![0.0; len]
    })
}

/// Return a tensor's storage to this thread's pool.
pub fn recycle(t: Tensor) {
    give(t.into_data());
}

/// Return a raw buffer to this thread's pool (dropped when the pool is
/// disabled, full, or already holds enough buffers of this length).
pub fn give(v: Vec<f32>) {
    let len = v.len();
    if len == 0 {
        return;
    }
    POOL.with(|cell| {
        let mut p = cell.borrow_mut();
        let pm = &mut *p;
        if !enabled(pm) || pm.bytes + 4 * len > BYTES_CAP {
            return;
        }
        let list = pm.by_len.entry(len).or_default();
        if list.len() < PER_LEN_CAP {
            list.push(v);
            pm.bytes += 4 * len;
        }
    })
}

/// (hits, misses) counters for this thread — observability for benches and
/// the reuse tests.
pub fn stats() -> (u64, u64) {
    POOL.with(|cell| {
        let p = cell.borrow();
        (p.hits, p.misses)
    })
}

/// Force reuse on/off for this thread (benches/tests); returns the previous
/// effective setting.  Disabling drops everything currently pooled.
pub fn set_enabled(on: bool) -> bool {
    POOL.with(|cell| {
        let mut p = cell.borrow_mut();
        let prev = enabled(&mut p);
        p.enabled = Some(on);
        if !on {
            p.by_len.clear();
            p.bytes = 0;
        }
        prev
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_returns_zeroed_buffers() {
        set_enabled(true);
        let (h0, _) = stats();
        let mut v = zeroed(1024);
        v.iter_mut().for_each(|x| *x = 7.0);
        let ptr = v.as_ptr();
        give(v);
        let v2 = zeroed(1024);
        let (h1, _) = stats();
        assert_eq!(h1, h0 + 1, "second request should hit the pool");
        assert_eq!(v2.as_ptr(), ptr, "allocation should be reused");
        assert!(v2.iter().all(|&x| x == 0.0), "reused buffer must be re-zeroed");
    }

    #[test]
    fn recycle_roundtrips_tensors() {
        set_enabled(true);
        let t = Tensor::ones(&[33, 7]);
        recycle(t);
        let v = zeroed(33 * 7);
        assert_eq!(v.len(), 33 * 7);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn disabled_pool_never_reuses() {
        set_enabled(false);
        let v = zeroed(256);
        let (h0, _) = stats();
        give(v);
        let _ = zeroed(256);
        let (h1, _) = stats();
        assert_eq!(h1, h0, "disabled pool must not hit");
        set_enabled(true);
    }

    #[test]
    fn zero_length_is_a_noop() {
        assert!(zeroed(0).is_empty());
        give(Vec::new());
    }
}
