//! `cargo bench --bench fig2_iterations` — regenerates the paper's fig2
//! (see coordinator::sweep for the experiment definition).
mod common;

fn main() {
    common::run_experiment("fig2");
}
