//! Hand-rolled HTTP/1.1 codec and the endpoint routing table.
//!
//! Zero-dependency by design (std `TcpStream` only): one request per
//! connection (`Connection: close`), bodies bounded by `Content-Length`,
//! JSON in/out through [`crate::util::json::Json`].  Endpoints:
//!
//! | route              | verb | body                                        |
//! |--------------------|------|---------------------------------------------|
//! | `/healthz`         | GET  | status + loaded variants                    |
//! | `/metrics`         | GET  | Prometheus text exposition                  |
//! | `/models`          | GET  | per-variant detail (params, sparsity, KV)   |
//! | `/models/load`     | POST | `{name, checkpoint[, model, max_active]}`   |
//! | `/generate`        | POST | `{prompt[, model, max_tokens, temperature]}`|
//! | `/score`           | POST | `{text[, model]}`                           |

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::batcher::{self, BatchCfg, EngineSpec};
use super::ServeState;

// ---------------------------------------------------------------------------
// HTTP codec.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
}

const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        let n = stream.read(&mut tmp).context("reading request head")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&tmp[..n]);
        if let Some(pos) = find(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request head too large");
        }
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().context("empty request")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("request body too large");
    }
    let body_start = header_end + 4;
    while buf.len() < body_start + content_length {
        let n = stream.read(&mut tmp).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let body =
        String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Ok(Request { method, path, body })
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())
}

/// One connection end-to-end: parse, route, respond.
pub fn serve_connection(state: &ServeState, stream: &mut TcpStream) {
    match read_request(stream) {
        Ok(req) => {
            state.http_requests.fetch_add(1, Ordering::Relaxed);
            let (status, ctype, body) = route(state, &req);
            let _ = respond(stream, status, ctype, &body);
        }
        Err(e) => {
            let _ = respond(stream, 400, "application/json", &err_body(&format!("{e:#}")));
        }
    }
}

// ---------------------------------------------------------------------------
// Routing.
// ---------------------------------------------------------------------------

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; version=0.0.4";

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

/// Prometheus label-value escaping (backslash, quote, newline).
fn label_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Variant names live in URLs, JSON and metric labels — keep them boring.
fn valid_variant_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | ':' | '@'))
}

pub fn route(state: &ServeState, req: &Request) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, JSON, healthz(state)),
        ("GET", "/metrics") => (200, TEXT, metrics(state)),
        ("GET", "/models") => (200, JSON, models(state)),
        ("POST", "/models/load") => {
            let (status, body) = models_load(state, &req.body);
            (status, JSON, body)
        }
        ("POST", "/generate") => {
            let (status, body) = generate(state, &req.body);
            (status, JSON, body)
        }
        ("POST", "/score") => {
            let (status, body) = score(state, &req.body);
            (status, JSON, body)
        }
        ("GET", _) | ("POST", _) => (404, JSON, err_body(&format!("no route {}", req.path))),
        _ => (405, JSON, err_body(&format!("method {} not allowed", req.method))),
    }
}

fn healthz(state: &ServeState) -> String {
    Json::obj(vec![
        ("status", Json::Str("ok".to_string())),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
        (
            "models",
            Json::Arr(state.names().into_iter().map(Json::Str).collect()),
        ),
    ])
    .to_string()
}

fn models(state: &ServeState) -> String {
    let entries: Vec<Json> = state
        .engines_snapshot()
        .into_iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("model", Json::Str(e.model.clone())),
                ("params", Json::Num(e.info.total_params as f64)),
                ("weight_sparsity", Json::Num(e.info.weight_sparsity)),
                ("slots", Json::Num(e.info.slots as f64)),
                ("max_active", Json::Num(e.info.max_active as f64)),
                ("seq_len", Json::Num(e.info.seq_len as f64)),
                ("kv_cache_bytes", Json::Num(e.info.kv_bytes as f64)),
                ("csr_weight_bytes", Json::Num(e.info.csr_bytes as f64)),
                (
                    "checkpoint",
                    e.info
                        .checkpoint
                        .clone()
                        .map(Json::Str)
                        .unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    Json::obj(vec![("models", Json::Arr(entries))]).to_string()
}

fn metrics(state: &ServeState) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "perp_serve_uptime_seconds {}\n",
        state.started.elapsed().as_secs_f64()
    ));
    out.push_str(&format!(
        "perp_serve_http_requests_total {}\n",
        state.http_requests.load(Ordering::Relaxed)
    ));
    for e in state.engines_snapshot() {
        let m = &e.metrics;
        let tag = format!("{{model=\"{}\"}}", label_escape(&e.name));
        let rows: [(&str, u64); 8] = [
            ("requests_total", m.requests.load(Ordering::Relaxed)),
            ("completed_total", m.completed.load(Ordering::Relaxed)),
            ("generated_tokens_total", m.gen_tokens.load(Ordering::Relaxed)),
            ("prefill_batches_total", m.prefills.load(Ordering::Relaxed)),
            ("decode_steps_total", m.decode_steps.load(Ordering::Relaxed)),
            ("queue_depth", m.queued.load(Ordering::Relaxed)),
            ("active_streams", m.active.load(Ordering::Relaxed)),
            ("peak_active_streams", m.peak_active.load(Ordering::Relaxed)),
        ];
        for (name, value) in rows {
            out.push_str(&format!("perp_serve_{name}{tag} {value}\n"));
        }
        out.push_str(&format!(
            "perp_serve_kv_cache_bytes{tag} {}\n",
            e.info.kv_bytes
        ));
        out.push_str(&format!(
            "perp_serve_csr_weight_bytes{tag} {}\n",
            e.info.csr_bytes
        ));
    }
    // process-wide obs registry: backend exec counts, SpMM layout dispatch,
    // tape-pool reuse, queue-wait / batch-fill / KV-occupancy histograms
    out.push_str(&crate::obs::counters::Registry::global().render_prometheus());
    out
}

fn generate(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return (400, err_body(&format!("bad json: {e}"))),
    };
    let Some(prompt) = j.get("prompt").and_then(Json::as_str) else {
        return (400, err_body("\"prompt\" is required"));
    };
    let model = j.str_or("model", &state.default_model);
    let max_new = j.get("max_tokens").and_then(Json::as_usize);
    let temperature = j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let Some(engine) = state.engine(&model) else {
        return (404, err_body(&format!("no model variant {model:?}")));
    };
    let t0 = Instant::now();
    match engine.generate(prompt.to_string(), max_new, temperature) {
        Ok(r) => (
            200,
            Json::obj(vec![
                ("model", Json::Str(model)),
                ("completion", Json::Str(r.completion)),
                (
                    "tokens",
                    Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                ("prompt_tokens", Json::Num(r.prompt_tokens as f64)),
                ("finish_reason", Json::Str(r.finish.to_string())),
                ("latency_ms", Json::Num(t0.elapsed().as_secs_f64() * 1e3)),
            ])
            .to_string(),
        ),
        Err(e) => (500, err_body(&format!("{e:#}"))),
    }
}

fn score(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return (400, err_body(&format!("bad json: {e}"))),
    };
    let Some(text) = j.get("text").and_then(Json::as_str) else {
        return (400, err_body("\"text\" is required"));
    };
    let model = j.str_or("model", &state.default_model);
    let Some(engine) = state.engine(&model) else {
        return (404, err_body(&format!("no model variant {model:?}")));
    };
    match engine.score(text.to_string()) {
        Ok(r) => (
            200,
            Json::obj(vec![
                ("model", Json::Str(model)),
                ("nll", Json::Num(r.nll)),
                ("ppl", Json::Num(r.ppl)),
                ("tokens", Json::Num(r.tokens as f64)),
            ])
            .to_string(),
        ),
        Err(e) => (400, err_body(&format!("{e:#}"))),
    }
}

/// Hot-load another checkpoint variant behind the running process.
fn models_load(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return (400, err_body(&format!("bad json: {e}"))),
    };
    let Some(name) = j.get("name").and_then(Json::as_str) else {
        return (400, err_body("\"name\" is required"));
    };
    if !valid_variant_name(name) {
        return (
            400,
            err_body("\"name\" must be 1-64 chars of [A-Za-z0-9._:@-]"),
        );
    }
    let Some(ckpt) = j.get("checkpoint").and_then(Json::as_str) else {
        return (400, err_body("\"checkpoint\" is required"));
    };
    if state.engine(name).is_some() {
        return (409, err_body(&format!("variant {name:?} already loaded")));
    }
    let mut cfg = state.base_cfg.clone();
    if let Some(m) = j.get("model").and_then(Json::as_str) {
        cfg.model = m.to_string();
    }
    let mut batch = BatchCfg::default();
    if let Some(a) = j.get("max_active").and_then(Json::as_usize) {
        batch.max_active = a;
    }
    let spec = EngineSpec {
        name: name.to_string(),
        cfg,
        seed: state.seed,
        checkpoint: Some(PathBuf::from(ckpt)),
        cache_dir: state.cache_dir.clone(),
        batch,
    };
    match batcher::spawn(spec) {
        Ok(handle) => match state.insert(handle) {
            Ok(()) => (
                200,
                Json::obj(vec![("loaded", Json::Str(name.to_string()))]).to_string(),
            ),
            Err(e) => (409, err_body(&format!("{e:#}"))),
        },
        Err(e) => (400, err_body(&format!("{e:#}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_finder() {
        assert_eq!(find(b"abc\r\n\r\nxyz", b"\r\n\r\n"), Some(3));
        assert_eq!(find(b"abc", b"\r\n\r\n"), None);
    }

    #[test]
    fn error_bodies_are_json() {
        let b = err_body("boom \"quoted\"");
        let j = Json::parse(&b).unwrap();
        assert_eq!(j.req("error").as_str().unwrap(), "boom \"quoted\"");
    }

    #[test]
    fn variant_names_are_validated_and_labels_escaped() {
        assert!(valid_variant_name("gpt-nano@0.5"));
        assert!(valid_variant_name("dense_v1.2:a"));
        assert!(!valid_variant_name(""));
        assert!(!valid_variant_name("a\"} 1\nfake{x=\""));
        assert!(!valid_variant_name(&"x".repeat(65)));
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
