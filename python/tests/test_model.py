"""L2 model-level tests: shapes, loss behaviour, trainable-subset isolation,
and parity of the LoRA-variant forwards at their identity initialisations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["gpt-nano"]


def init_params(cfg, seed=0):
    r = np.random.default_rng(seed)
    params = {}
    for n, s, g in M.param_specs(cfg):
        if n.endswith("_scale"):
            params[n] = np.ones(s, np.float32)
        elif n.endswith("_b") or n.endswith("_bias"):
            params[n] = np.zeros(s, np.float32)
        else:
            params[n] = (r.standard_normal(s) * 0.02).astype(np.float32)
    return params


def ones_masks(cfg):
    shapes = {n: s for n, s, _ in M.param_specs(cfg)}
    return {n: np.ones(shapes[n], np.float32) for n in M.prunable_names(cfg)}


def rand_tokens(cfg, b, seed=1):
    r = np.random.default_rng(seed)
    return r.integers(0, cfg.vocab, size=(b, cfg.seq_len)).astype(np.int32)


def zero_adapters(cfg, seed=2):
    r = np.random.default_rng(seed)
    ad = {}
    for n, s in M.adapter_specs(cfg):
        if n.endswith("::A"):
            ad[n] = (r.standard_normal(s) * 0.1).astype(np.float32)
        else:
            ad[n] = np.zeros(s, np.float32)
    return ad


def test_param_specs_cover_all_groups():
    groups = {g for _, _, g in M.param_specs(CFG)}
    assert groups == {"embed", "ln", "bias", "weight", "head"}
    # llama-style has no biases and no ln biases
    lcfg = M.CONFIGS["llama-tiny"]
    lgroups = {g for _, _, g in M.param_specs(lcfg)}
    assert "bias" not in lgroups
    assert not any(n.endswith("ln1_bias") for n, _, _ in M.param_specs(lcfg))


def test_trainable_fractions_ordering():
    """The paper's core quantitative frame: |LN| < |biases| << |lora| << all."""
    shapes = {n: int(np.prod(s)) for n, s, _ in M.param_specs(CFG)}
    total = sum(shapes.values())
    sizes = {}
    for mode in ("ln", "biases", "full"):
        names = M.trainable_names(CFG, mode)
        sizes[mode] = sum(shapes[n] for n in names)
    lora_extra = sum(int(np.prod(s)) for _, s in M.adapter_specs(CFG))
    assert sizes["ln"] < sizes["biases"] < lora_extra < total
    assert sizes["full"] == total


def test_forward_shapes_and_determinism():
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, 2)
    logits = M.forward(CFG, params, masks, toks)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    logits2 = M.forward(CFG, params, masks, toks)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))


def test_loss_near_uniform_at_init():
    """Random init ⇒ CE ≈ log(V)."""
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, 4)
    logits = M.forward(CFG, params, masks, toks)
    loss = float(M.lm_loss_mean(logits, toks))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_masking_zeroes_weights_effectively():
    """An all-zero mask on every linear must change the logits vs dense."""
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, 2)
    dense = np.asarray(M.forward(CFG, params, masks, toks))
    zmasks = {k: np.zeros_like(v) for k, v in masks.items()}
    zeroed = np.asarray(M.forward(CFG, params, zmasks, toks))
    assert not np.allclose(dense, zeroed)


@pytest.mark.parametrize("mode", ["lora", "masklora", "masklora_std"])
def test_lora_identity_at_zero_B(mode):
    """B=0 ⇒ every additive LoRA variant equals the plain pruned forward."""
    params = init_params(CFG)
    masks = {k: (np.random.default_rng(3).random(v.shape) > 0.5).astype(np.float32)
             for k, v in ones_masks(CFG).items()}
    toks = rand_tokens(CFG, 2)
    base = np.asarray(M.forward(CFG, params, masks, toks))
    ad = zero_adapters(CFG)
    out = np.asarray(M.forward(CFG, params, masks, toks, adapters=ad, mode=mode))
    np.testing.assert_allclose(base, out, atol=1e-5, rtol=1e-5)


def test_scalelora_identity_at_ones_init():
    from compile.kernels import scale_lora_init

    params = init_params(CFG)
    masks = {k: (np.random.default_rng(4).random(v.shape) > 0.5).astype(np.float32)
             for k, v in ones_masks(CFG).items()}
    toks = rand_tokens(CFG, 2)
    base = np.asarray(M.forward(CFG, params, masks, toks))
    shapes = {n: s for n, s, _ in M.param_specs(CFG)}
    ad = {}
    for n in M.prunable_names(CFG):
        o, i = shapes[n]
        a, b = scale_lora_init(o, i, CFG.lora_rank)
        ad[n + "::A"] = np.asarray(a)
        ad[n + "::B"] = np.asarray(b)
    out = np.asarray(M.forward(CFG, params, masks, toks, adapters=ad, mode="scalelora"))
    np.testing.assert_allclose(base, out, atol=1e-4, rtol=1e-4)


def test_subset_step_reduces_loss_and_respects_freeze():
    """A biases-only train step must (a) reduce loss over a few iterations,
    (b) leave every frozen parameter byte-identical."""
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, CFG.train_batch)
    step = M.make_train_step(CFG, "biases")
    tnames = M.trainable_names(CFG, "biases")
    trainable = {k: jnp.asarray(params[k]) for k in tnames}
    m = {k: jnp.zeros_like(v) for k, v in trainable.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in trainable.items()}
    losses = []
    frozen = {k: jnp.asarray(p) for k, p in params.items()}
    for i in range(5):
        for k in trainable:
            frozen[k] = trainable[k]
        trainable, m, v, loss = step(
            trainable, frozen, masks, None, m, v, toks, jnp.float32(i + 1),
            jnp.float32(5e-2),
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    for k, p0 in params.items():
        if k not in tnames:
            np.testing.assert_array_equal(np.asarray(frozen[k]), p0)


def test_masklora_step_trains_adapters_and_subsets_only():
    params = init_params(CFG)
    masks = {k: (np.random.default_rng(5).random(v.shape) > 0.3).astype(np.float32)
             for k, v in ones_masks(CFG).items()}
    toks = rand_tokens(CFG, CFG.train_batch)
    step = M.make_train_step(CFG, "masklora")
    tnames = M.trainable_names(CFG, "masklora")
    adapters = zero_adapters(CFG)
    leaves = {k: jnp.asarray(params[k]) for k in tnames}
    all_leaf = dict(leaves)
    all_leaf.update({k: jnp.asarray(val) for k, val in adapters.items()})
    m = {k: jnp.zeros_like(val) for k, val in all_leaf.items()}
    v = {k: jnp.zeros_like(val) for k, val in all_leaf.items()}
    frozen = {k: jnp.asarray(p) for k, p in params.items()}
    new_leaves, m2, v2, loss = step(
        leaves, frozen, masks,
        {k: jnp.asarray(val) for k, val in adapters.items()},
        m, v, toks, jnp.float32(1), jnp.float32(1e-3),
    )
    assert np.isfinite(float(loss))
    # adapters received gradient (B moves away from zero after one step)
    moved = sum(
        float(np.abs(np.asarray(new_leaves[k])).max()) > 0
        for k in adapters if k.endswith("::B")
    )
    assert moved > 0


def test_sequence_scores_mask_selectivity():
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, 2)
    logits = M.forward(CFG, params, masks, toks)
    tmask = np.zeros((2, CFG.seq_len), np.float32)
    tmask[:, 5:10] = 1.0
    scores, counts = M.sequence_scores(logits, toks, tmask)
    assert scores.shape == (2,)
    np.testing.assert_array_equal(np.asarray(counts), [5.0, 5.0])
    assert np.all(np.asarray(scores) < 0)


def test_calib_stats_gram_psd_and_shapes():
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, CFG.eval_batch)
    grams = M.calib_stats(CFG, params, masks, toks)
    names = [n for n, _ in grams]
    # one tap per distinct activation: q/k/v share their input (tap_names)
    assert names == M.tap_names(CFG)
    assert {M.tap_of(n) for n in M.prunable_names(CFG)} == set(names)
    for _, g in grams:
        g = np.asarray(g)
        assert g.shape[0] == g.shape[1]
        np.testing.assert_allclose(g, g.T, atol=1e-3)
        ev = np.linalg.eigvalsh(g.astype(np.float64))
        assert ev.min() > -1e-2 * max(1.0, ev.max())  # PSD up to float noise


def test_capture_inputs_match_gram():
    params = init_params(CFG)
    masks = ones_masks(CFG)
    toks = rand_tokens(CFG, CFG.eval_batch)
    caps = M.capture_layer_inputs(CFG, params, masks, toks)
    grams = dict(M.calib_stats(CFG, params, masks, toks))
    for name, x in caps:
        x = np.asarray(x)
        np.testing.assert_allclose(x.T @ x, np.asarray(grams[name]),
                                   atol=5e-2, rtol=1e-3)
