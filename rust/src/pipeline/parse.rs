//! The inline `--stages` grammar.
//!
//! ```text
//! spec    := stage ('|' stage)*
//! stage   := name [ '(' arg (',' arg)* ')' ]
//! name    := pretrain | prune | retrain | reconstruct | merge | eval | export
//! ```
//!
//! Examples:
//!
//! ```text
//! prune(wanda,0.5)|retrain(masklora,100)|merge|eval
//! prune(magnitude,2:4)|reconstruct(full)|eval(ppl)|export(results/m.ptns)
//! ```
//!
//! Positional args mirror the JSON fields: `prune(criterion,sparsity)`,
//! `retrain(mode[,steps[,lr]])`, `reconstruct(mode[,steps[,lr]])`,
//! `eval([ppl|tasks])`, `export(path)`.  A leading `pretrain` is implied
//! when absent — every plan starts from the (cached) dense model.

use crate::peft::Mode;
use crate::pruning::{Criterion, Pattern};

use super::plan::{recon_mode_parse, Plan, Stage};

/// Parse one `|`-separated stage spec into stages (no implied pretrain).
pub fn parse_stages(spec: &str) -> Result<Vec<Stage>, String> {
    spec.split('|')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(parse_stage)
        .collect()
}

/// Parse a spec into a runnable [`Plan`], prepending `pretrain` if absent.
pub fn parse_plan(name: &str, spec: &str) -> Result<Plan, String> {
    let mut stages = parse_stages(spec)?;
    if stages.is_empty() {
        return Err("empty stage spec".to_string());
    }
    if stages[0] != Stage::Pretrain {
        stages.insert(0, Stage::Pretrain);
    }
    Ok(Plan { name: name.to_string(), stages })
}

fn parse_stage(s: &str) -> Result<Stage, String> {
    let (name, args) = match s.find('(') {
        None => (s, Vec::new()),
        Some(open) => {
            let Some(stripped) = s[open..].strip_prefix('(').and_then(|r| r.strip_suffix(')'))
            else {
                return Err(format!("malformed stage {s:?} (unbalanced parentheses)"));
            };
            let args: Vec<&str> = stripped
                .split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .collect();
            (&s[..open], args)
        }
    };
    let argc = |max: usize| -> Result<(), String> {
        if args.len() > max {
            Err(format!("{name}: too many arguments in {s:?} (max {max})"))
        } else {
            Ok(())
        }
    };
    match name {
        "pretrain" => {
            argc(0)?;
            Ok(Stage::Pretrain)
        }
        "prune" => {
            argc(2)?;
            let criterion = Criterion::parse(args.first().copied().unwrap_or("magnitude"))?;
            let pattern = Pattern::parse(args.get(1).copied().unwrap_or("0.5"))?;
            Ok(Stage::Prune { criterion, pattern })
        }
        "retrain" => {
            argc(3)?;
            let mode = Mode::parse(
                args.first()
                    .copied()
                    .ok_or_else(|| "retrain needs a mode, e.g. retrain(masklora)".to_string())?,
            )?;
            Ok(Stage::Retrain {
                mode,
                steps: parse_opt_u64(&args, 1, s)?,
                lr: parse_opt_f64(&args, 2, s)?,
            })
        }
        "reconstruct" => {
            argc(3)?;
            let mode = recon_mode_parse(args.first().copied().unwrap_or("masklora"))?;
            Ok(Stage::Reconstruct {
                mode,
                steps: parse_opt_u64(&args, 1, s)?,
                lr: parse_opt_f64(&args, 2, s)?,
            })
        }
        "merge" => {
            argc(0)?;
            Ok(Stage::Merge)
        }
        "eval" => {
            argc(1)?;
            let tasks = match args.first().copied() {
                None | Some("tasks") => true,
                Some("ppl") => false,
                Some(other) => return Err(format!("eval: unknown arg {other:?} (ppl|tasks)")),
            };
            Ok(Stage::Eval { tasks })
        }
        "export" => {
            argc(1)?;
            let path = args
                .first()
                .copied()
                .ok_or_else(|| "export needs a path, e.g. export(results/m.ptns)".to_string())?;
            Ok(Stage::Export { path: path.to_string() })
        }
        other => Err(format!(
            "unknown stage {other:?} (pretrain|prune|retrain|reconstruct|merge|eval|export)"
        )),
    }
}

fn parse_opt_u64(args: &[&str], idx: usize, ctx: &str) -> Result<Option<u64>, String> {
    match args.get(idx) {
        None => Ok(None),
        Some(v) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|_| format!("{ctx}: expected an integer, got {v:?}")),
    }
}

fn parse_opt_f64(args: &[&str], idx: usize, ctx: &str) -> Result<Option<f64>, String> {
    match args.get(idx) {
        None => Ok(None),
        Some(v) => v
            .parse::<f64>()
            .map(Some)
            .map_err(|_| format!("{ctx}: expected a number, got {v:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_example_parses() {
        let p = parse_plan("inline", "prune(wanda,0.5)|retrain(masklora,100)|merge|eval").unwrap();
        assert_eq!(
            p.stages,
            vec![
                Stage::Pretrain,
                Stage::Prune { criterion: Criterion::Wanda, pattern: Pattern::Unstructured(0.5) },
                Stage::Retrain { mode: Mode::MaskLora, steps: Some(100), lr: None },
                Stage::Merge,
                Stage::Eval { tasks: true },
            ]
        );
        p.validate().unwrap();
    }

    #[test]
    fn defaults_and_explicit_pretrain() {
        let p = parse_plan("x", "pretrain|prune|eval(ppl)").unwrap();
        assert_eq!(p.stages.len(), 3);
        assert_eq!(
            p.stages[1],
            Stage::Prune {
                criterion: Criterion::Magnitude,
                pattern: Pattern::Unstructured(0.5)
            }
        );
        assert_eq!(p.stages[2], Stage::Eval { tasks: false });
    }

    #[test]
    fn nm_patterns_reconstruct_and_export() {
        let p = parse_plan(
            "x",
            "prune(sparsegpt,2:4)|reconstruct(full,20,0.002)|eval|export(out/m.ptns)",
        )
        .unwrap();
        assert_eq!(
            p.stages[1],
            Stage::Prune {
                criterion: Criterion::SparseGpt,
                pattern: Pattern::SemiStructured { n: 2, m: 4 }
            }
        );
        assert_eq!(
            p.stages[2],
            Stage::Reconstruct {
                mode: crate::coordinator::reconstruct::ReconMode::FullFt,
                steps: Some(20),
                lr: Some(2e-3),
            }
        );
        assert_eq!(p.stages[4], Stage::Export { path: "out/m.ptns".to_string() });
    }

    #[test]
    fn errors_are_clean() {
        assert!(parse_stages("prune(wanda,0.5").is_err());
        assert!(parse_stages("retrain").is_err());
        assert!(parse_stages("retrain(masklora,abc)").is_err());
        assert!(parse_stages("fly(me)").is_err());
        assert!(parse_stages("eval(everything)").is_err());
        assert!(parse_plan("x", " | ").is_err());
    }

    #[test]
    fn roundtrips_through_json() {
        let p = parse_plan("x", "prune(wanda,0.7)|retrain(scalelora,5,0.01)|merge|eval").unwrap();
        let p2 = Plan::from_text(&p.to_json().to_string()).unwrap();
        assert_eq!(p, p2);
    }
}
