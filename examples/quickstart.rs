//! Quickstart: the PERP story in one minute on gpt-nano — written against
//! the `perp::pipeline` builder API.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! 1. pretrain (or load the cached) dense model;
//! 2. magnitude-prune 50% → perplexity degrades;
//! 3. retrain ONLY the biases (≈1% of params at this scale, 0.03% at OPT
//!    scale) → most of the damage is gone;
//! 4. retrain with MaskLoRA and merge losslessly → sparsity preserved.
//!
//! The four plans below share their `pretrain|prune` prefix, so the
//! executor's content-addressed cache computes it once — watch the
//! "cache hit" lines on every plan after the first (and on re-runs).

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::peft::Mode;
use perp::pipeline::{Executor, Plan};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::open_default_backend;

fn main() -> Result<()> {
    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::quick("gpt-nano");
    cfg.pretrain_steps = 3000;
    cfg.retrain_steps = 150;
    let ex = Executor::new(rt.as_ref(), cfg, "results/cache".into(), 0);

    println!("== 1. dense model ==");
    let dense = ex.run(&Plan::new("quickstart-dense").pretrain().eval_ppl())?;
    let dense_ppl = dense.last_metrics().expect("eval ran").ppl;
    println!("dense test perplexity: {dense_ppl:.2}");

    println!("\n== 2. magnitude pruning @ 50% ==");
    let pruned = ex.run(
        &Plan::new("quickstart-pruned")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .eval_ppl(),
    )?;
    let pm = pruned.last_metrics().expect("eval ran");
    println!(
        "pruned perplexity: {:.2}  (x{:.2} vs dense) — sparsity {:.1}%",
        pm.ppl,
        pm.ppl / dense_ppl,
        100.0 * pm.sparsity
    );

    println!("\n== 3. retrain ONLY the biases ==");
    let biases = ex.run(
        &Plan::new("quickstart-biases")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::Biases, None, None)
            .eval_ppl(),
    )?;
    let bias_ppl = biases.last_metrics().expect("eval ran").ppl;
    let bias_pct = biases
        .stages
        .iter()
        .find_map(|s| s.trainable_pct)
        .unwrap_or(0.0);
    println!(
        "biases retrained: perplexity {bias_ppl:.2} — trainable {bias_pct:.3}% of params"
    );

    println!("\n== 4. MaskLoRA: mergeable, sparsity-preserving ==");
    let ml = ex.run(
        &Plan::new("quickstart-masklora")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::MaskLora, None, None)
            .merge() // panics if any pruned weight were resurrected
            .eval_ppl(),
    )?;
    let mlm = ml.last_metrics().expect("eval ran");
    println!(
        "masklora retrained+merged: perplexity {:.2}; post-merge sparsity {:.1}%",
        mlm.ppl,
        100.0 * mlm.sparsity
    );

    println!(
        "\nsummary: dense {:.2} | pruned {:.2} | +biases {:.2} | +masklora {:.2}",
        dense_ppl, pm.ppl, bias_ppl, mlm.ppl
    );
    Ok(())
}
