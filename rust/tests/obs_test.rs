//! Integration tests for the `perp::obs` layer: the disabled path records
//! (and allocates) nothing, a parallel graph run traces one span per
//! executed node on named worker tracks, counter snapshot/diff arithmetic
//! holds, and — the load-bearing invariant — stage artifacts are
//! bitwise-identical whether tracing is on or off.
//!
//! Tracing/logging state is process-global, so every test that flips it
//! serializes through one lock (other test files run as separate
//! binaries and are unaffected).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use perp::config::ExperimentConfig;
use perp::obs::counters::Registry;
use perp::obs::trace;
use perp::pipeline::{Executor, GraphBuilder, Plan};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::NativeBackend;
use perp::util::json::Json;

static GATE: Mutex<()> = Mutex::new(());

fn cfg(retrain_steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 120;
    c.retrain_steps = retrain_steps;
    c.recon_steps = 6;
    c.calib_seqs = 8;
    c.items_per_task = 6;
    c.eval_batches = 2;
    c
}

#[test]
fn disabled_tracing_buffers_nothing() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::configure(false, None);
    let before = trace::buffered();
    for _ in 0..100 {
        let sp = perp::span!("test", "disabled {}", "span");
        assert!(!sp.is_recording());
    }
    assert_eq!(
        trace::buffered(),
        before,
        "spans created while tracing is off must never reach the ring buffer"
    );
}

#[test]
fn parallel_graph_run_traces_every_node_on_named_worker_tracks() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let rt = NativeBackend::new();
    let dir = std::env::temp_dir().join("perp_obs_test_traced");
    std::fs::remove_dir_all(&dir).ok();

    let g = GraphBuilder::new("traced_fan")
        .pretrain()
        .fork_sparsities(Criterion::Magnitude, &[0.5, 0.7, 0.9])
        .eval_ppl()
        .build();

    trace::configure(true, None);
    trace::drain();
    let report = Executor::new(&rt, cfg(31), dir.clone(), 0)
        .quiet(true)
        .jobs(4)
        .run_graph(&g)
        .unwrap();
    trace::configure(false, None);
    assert_eq!(report.computed(), g.stage_count(), "fresh cache computes all");

    let out = dir.join("trace.json");
    let (path, spans) = trace::flush(Some(&out)).unwrap().expect("traced run must flush spans");
    assert!(spans >= g.stage_count());

    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = doc.req("traceEvents").as_arr().unwrap();

    fn field(e: &Json, k: &str) -> Option<String> {
        e.get(k).and_then(Json::as_str).map(str::to_string)
    }
    // one "node" span per executed graph node, names matching exactly
    let node_spans: Vec<String> = events
        .iter()
        .filter(|e| {
            field(e, "ph").as_deref() == Some("X") && field(e, "cat").as_deref() == Some("node")
        })
        .filter_map(|e| field(e, "name"))
        .collect();
    let expected: std::collections::BTreeSet<String> = g
        .nodes
        .iter()
        .filter(|n| n.stage().is_some())
        .map(|n| n.name.clone())
        .collect();
    let got: std::collections::BTreeSet<String> = node_spans.iter().cloned().collect();
    assert_eq!(got, expected, "every stage node gets exactly one node span");
    assert_eq!(node_spans.len(), expected.len(), "no duplicate node spans");

    // `--jobs 4` workers are spawned with stable names that become
    // thread_name metadata tracks in the Chrome viewer
    let worker_tracks = events
        .iter()
        .filter(|e| field(e, "ph").as_deref() == Some("M"))
        .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str))
        .filter(|n| n.starts_with("plan-worker-"))
        .count();
    assert!(worker_tracks >= 1, "node spans must land on named worker tracks");

    // every complete event is well-formed (non-negative timestamps and
    // durations; the JSONL twin parses line by line)
    for e in events.iter().filter(|e| field(e, "ph").as_deref() == Some("X")) {
        assert!(e.req("ts").as_f64().unwrap() >= 0.0);
        assert!(e.req("dur").as_f64().unwrap() >= 0.0);
    }
    let jsonl = std::fs::read_to_string(path.with_extension("jsonl")).unwrap();
    assert!(jsonl.lines().count() >= g.stage_count());
    for line in jsonl.lines() {
        Json::parse(line).unwrap();
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn counter_snapshots_diff_exactly() {
    let reg = Registry::new();
    reg.add("a", 5);
    reg.add("b", 2);
    reg.observe("lat", 3.0);
    let s0 = reg.snapshot();
    reg.add("a", 7);
    reg.add("c", 1);
    reg.observe("lat", 4.0);
    let delta = reg.snapshot().since(&s0);
    let want: BTreeMap<String, u64> =
        [("a".to_string(), 7), ("c".to_string(), 1)].into_iter().collect();
    assert_eq!(delta.counters, want, "unchanged counters drop out of the diff");
    let lat = &delta.hists["lat"];
    assert_eq!(lat.count, 1, "one new histogram observation since the snapshot");
    assert!((lat.sum - 4.0).abs() < 1e-12);

    // the count! macro feeds the global registry through a cached handle
    let g0 = Registry::global().snapshot();
    perp::count!("obs_test.macro");
    perp::count!("obs_test.macro", 4);
    let gd = Registry::global().snapshot().since(&g0);
    assert_eq!(gd.counters.get("obs_test.macro"), Some(&5));
}

/// Recursively collect relative-path -> bytes for every file under `dir`.
fn dir_bytes(dir: &Path, base: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
    for e in std::fs::read_dir(dir).unwrap().flatten() {
        let p = e.path();
        if p.is_dir() {
            dir_bytes(&p, base, out);
        } else {
            let rel = p.strip_prefix(base).unwrap().to_string_lossy().into_owned();
            out.insert(rel, std::fs::read(&p).unwrap());
        }
    }
}

#[test]
fn stage_artifacts_are_bitwise_identical_with_tracing_on_and_off() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let rt = NativeBackend::new();
    let dir_on = std::env::temp_dir().join("perp_obs_test_art_on");
    let dir_off = std::env::temp_dir().join("perp_obs_test_art_off");
    std::fs::remove_dir_all(&dir_on).ok();
    std::fs::remove_dir_all(&dir_off).ok();

    let plan = Plan::new("obs_art")
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(0.6))
        .eval_ppl();

    trace::configure(true, None);
    trace::drain();
    let traced =
        Executor::new(&rt, cfg(32), dir_on.clone(), 0).quiet(true).run(&plan).unwrap();
    trace::configure(false, None);
    trace::drain();
    let plain =
        Executor::new(&rt, cfg(32), dir_off.clone(), 0).quiet(true).run(&plan).unwrap();

    assert_eq!(traced.stages.len(), plain.stages.len());
    for (a, b) in traced.stages.iter().zip(&plain.stages) {
        assert_eq!(a.key, b.key, "tracing must not perturb stage keys");
        // compare the stage dirs byte for byte: observability writes its
        // volatile data (wall clock, counters) to sidecars *outside* these
        // dirs, so their contents must not differ by a single bit
        let (mut on, mut off) = (BTreeMap::new(), BTreeMap::new());
        let da = dir_on.join("plan").join(&a.key);
        let db = dir_off.join("plan").join(&b.key);
        dir_bytes(&da, &da, &mut on);
        dir_bytes(&db, &db, &mut off);
        assert!(!on.is_empty(), "stage {} wrote no artifacts", a.label);
        assert_eq!(
            on.keys().collect::<Vec<_>>(),
            off.keys().collect::<Vec<_>>(),
            "stage {} file sets differ",
            a.label
        );
        for (rel, bytes) in &on {
            assert_eq!(
                Some(bytes),
                off.get(rel),
                "stage {} file {rel} differs between traced and untraced runs",
                a.label
            );
        }
    }

    std::fs::remove_dir_all(&dir_on).ok();
    std::fs::remove_dir_all(&dir_off).ok();
}
