//! Property-based testing harness (proptest replacement).
//!
//! `check(name, cases, |g| {...})` runs the closure against `cases`
//! independently seeded generator states; on failure it reports the seed that
//! reproduces.  [`Gen`] wraps [`super::rng::Rng`] with size-biased helpers for
//! the shapes/densities this crate cares about.

use super::rng::Rng;

pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Dimension in [1, max], biased toward small and boundary values.
    pub fn dim(&mut self, max: usize) -> usize {
        match self.rng.below(10) {
            0 => 1,
            1 => max,
            2 => (max / 2).max(1),
            _ => 1 + self.rng.below(max as u64) as usize,
        }
    }

    /// Dimension that is a multiple of `m`, in [m, max].
    pub fn dim_multiple_of(&mut self, m: usize, max: usize) -> usize {
        let k = (max / m).max(1);
        m * (1 + self.rng.below(k as u64) as usize)
    }

    pub fn sparsity(&mut self) -> f32 {
        *self.rng.choice(&[0.0, 0.1, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0])
    }

    pub fn tensor(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }
}

/// Run `f` for `cases` generated inputs; panic with the failing seed on error.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case} (reproduce with PERP_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

fn base_seed() -> u64 {
    std::env::var("PERP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        check("count", 25, |g| {
            let d = g.dim(64);
            assert!((1..=64).contains(&d));
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 25);
    }

    #[test]
    fn dim_multiple_respects_divisor() {
        check("dims", 50, |g| {
            let d = g.dim_multiple_of(8, 128);
            assert_eq!(d % 8, 0);
            assert!(d >= 8 && d <= 128);
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("fails", 3, |g| {
            assert!(g.dim(4) > 100);
        });
    }
}
