//! KV-cache decode correctness: greedy decoding through the serving
//! executables (`prefill` + repeated `decode_step`) must produce
//! token-identical output to re-running the growing context through the
//! full forward pass — on gpt-nano, dense and at 50% unstructured
//! sparsity, for single and batched (multi-slot) streams, and under the
//! compressed weight layouts: CSR at 90%, BSR at 2:4 and at 90%
//! unstructured, and `auto` (which routes 2:4 masks to BSR).
//!
//! The decode kernels mirror the forward pass' accumulation order exactly
//! (the CSR/BSR SpMM kernels mirror the masked kernels' order in turn), so
//! this holds bitwise within a layout, not just within tolerance.

use std::collections::BTreeMap;

use perp::model::init;
use perp::pruning::{magnitude, Pattern};
use perp::runtime::native::graph::{self, GraphIn, ModeKind};
use perp::runtime::{Backend, Feed, ModelManifest, NativeBackend};
use perp::server::batcher::argmax;
use perp::server::kv::KvCache;
use perp::server::spec::{RoundInput, SpecEngine};
use perp::tensor::sparse::{LayoutPolicy, SparseStore, WeightLayout};
use perp::tensor::Tensor;
use perp::util::rng::Rng;

struct Fixture {
    be: NativeBackend,
    mm: ModelManifest,
    params: BTreeMap<String, Tensor>,
    masks: BTreeMap<String, Tensor>,
    /// Cached compressed forms under the fixture's layout (empty for Masked).
    sparse: SparseStore,
}

fn fixture(sparsity: Option<f64>) -> Fixture {
    fixture_with_layout(sparsity, LayoutPolicy::Fixed(WeightLayout::Masked))
}

fn fixture_with_layout(sparsity: Option<f64>, layout: LayoutPolicy) -> Fixture {
    fixture_pattern(sparsity.map(Pattern::Unstructured), layout)
}

fn fixture_pattern(pattern: Option<Pattern>, layout: LayoutPolicy) -> Fixture {
    let be = NativeBackend::new();
    let mm = be.model("gpt-nano").unwrap().clone();
    let mut rng = Rng::new(42);
    let params: BTreeMap<String, Tensor> =
        init::init_params(&mm, &mut rng).map().clone();
    let masks: BTreeMap<String, Tensor> = match pattern {
        None => mm
            .prunable
            .iter()
            .map(|n| (n.clone(), Tensor::ones(mm.param_shape(n))))
            .collect(),
        Some(p) => {
            let weights: BTreeMap<String, &Tensor> =
                mm.prunable.iter().map(|n| (n.clone(), &params[n])).collect();
            magnitude::uniform(&weights, p).masks
        }
    };
    let sparse = SparseStore::build(
        layout,
        mm.prunable.iter().map(|n| (n.clone(), &params[n], &masks[n])),
    );
    Fixture { be, mm, params, masks, sparse }
}

impl Fixture {
    fn graph_in<'a>(
        &'a self,
        params: &'a BTreeMap<String, &'a Tensor>,
        masks: &'a BTreeMap<String, &'a Tensor>,
    ) -> GraphIn<'a> {
        GraphIn {
            mm: &self.mm,
            params,
            masks,
            adapters: None,
            mode: ModeKind::Subset,
            sparse: self.sparse.view(),
        }
    }

    /// Reference: grow the sequence one token at a time, re-running the
    /// full padded forward pass and taking argmax at the last position.
    fn reference_greedy(&self, prompt: &[i32], steps: usize) -> Vec<i32> {
        let s = self.mm.cfg.seq_len;
        let vocab = self.mm.cfg.vocab;
        let params: BTreeMap<String, &Tensor> =
            self.params.iter().map(|(k, v)| (k.clone(), v)).collect();
        let masks: BTreeMap<String, &Tensor> =
            self.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
        let gi = self.graph_in(&params, &masks);
        let mut seq = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..steps {
            if seq.len() >= s {
                break;
            }
            let mut toks = vec![0i32; s];
            toks[..seq.len()].copy_from_slice(&seq);
            let tape = graph::forward(&gi, &toks, 1, s);
            let row = &tape.logits.data()[(seq.len() - 1) * vocab..seq.len() * vocab];
            let t = argmax(row);
            out.push(t);
            seq.push(t);
        }
        out
    }

    fn base_feed<'a>(&'a self, mut feed: Feed<'a>) -> Feed<'a> {
        for (n, t) in &self.params {
            feed = feed.owned_key(format!("p::{n}"), t);
        }
        for (n, t) in &self.masks {
            feed = feed.owned_key(format!("m::{n}"), t);
        }
        feed.sparse(&self.sparse)
    }

    /// KV path: one prefill over all prompts (each in its own slot), then
    /// lock-step `decode_step` until every stream has `steps` tokens.
    fn kv_greedy(&self, prompts: &[Vec<i32>], steps: usize) -> Vec<Vec<i32>> {
        let cfg = &self.mm.cfg;
        let (slots, s, vocab) = (cfg.serve_slots, cfg.seq_len, cfg.vocab);
        assert!(prompts.len() <= slots);
        let mut cache = KvCache::new(cfg);
        let assigned: Vec<usize> = prompts.iter().map(|_| cache.alloc().unwrap()).collect();

        let mut ptoks = vec![0i32; slots * s];
        let mut lens = vec![0i32; slots];
        for (p, &slot) in prompts.iter().zip(&assigned) {
            ptoks[slot * s..slot * s + p.len()].copy_from_slice(p);
            lens[slot] = p.len() as i32;
        }
        let pshape = [slots, s];
        let sshape = [slots];
        let out = {
            let feed = self
                .base_feed(Feed::new())
                .ints("tokens", &pshape, &ptoks)
                .ints("lens", &sshape, &lens);
            self.be.run("gpt-nano", "prefill", &feed).unwrap()
        };
        for layer in 0..cache.n_layers() {
            let k = out.get(&format!("k::h{layer}"));
            let v = out.get(&format!("v::h{layer}"));
            for &slot in &assigned {
                cache.adopt_prefill(slot, layer, k, v);
            }
        }
        let mut pos: Vec<usize> = prompts.iter().map(Vec::len).collect();
        let mut last: Vec<i32> = assigned
            .iter()
            .map(|&slot| argmax(&out.get("logits").data()[slot * vocab..(slot + 1) * vocab]))
            .collect();
        let mut results: Vec<Vec<i32>> = last.iter().map(|&t| vec![t]).collect();

        loop {
            let mut step_tokens = vec![0i32; slots];
            let mut step_pos = vec![-1i32; slots];
            let mut any = false;
            for (r, &slot) in assigned.iter().enumerate() {
                if results[r].len() < steps && pos[r] < s {
                    step_tokens[slot] = last[r];
                    step_pos[slot] = pos[r] as i32;
                    any = true;
                }
            }
            if !any {
                break;
            }
            let out = {
                let mut feed = self
                    .base_feed(Feed::new())
                    .ints("tokens", &sshape, &step_tokens)
                    .ints("pos", &sshape, &step_pos);
                for layer in 0..cache.n_layers() {
                    feed = feed
                        .owned_key(format!("k::h{layer}"), &cache.k[layer])
                        .owned_key(format!("v::h{layer}"), &cache.v[layer]);
                }
                self.be.run("gpt-nano", "decode_step", &feed).unwrap()
            };
            for (r, &slot) in assigned.iter().enumerate() {
                if step_pos[slot] < 0 {
                    continue;
                }
                for layer in 0..cache.n_layers() {
                    let kn = out.get(&format!("knew::h{layer}"));
                    let vn = out.get(&format!("vnew::h{layer}"));
                    cache.write_new(slot, pos[r], layer, kn, vn);
                }
                pos[r] += 1;
                let t =
                    argmax(&out.get("logits").data()[slot * vocab..(slot + 1) * vocab]);
                last[r] = t;
                results[r].push(t);
            }
        }
        results
    }
}

/// Speculative decode: the draft fixture proposes K tokens per round, the
/// target fixture verifies them through `verify_step`, and [`SpecEngine`]
/// owns all cache writes and rollbacks.  Returns `steps` greedy tokens per
/// prompt — which must be bitwise what target-only decoding emits, no
/// matter how good or bad the draft is.
fn spec_greedy(
    target: &Fixture,
    draft: &Fixture,
    prompts: &[Vec<i32>],
    steps: usize,
    k: usize,
) -> Vec<Vec<i32>> {
    let cfg = &target.mm.cfg;
    let (slots, s, vocab, sw) = (cfg.serve_slots, cfg.seq_len, cfg.vocab, cfg.spec_width);
    assert!(prompts.len() <= slots);
    let mut cache = KvCache::new(cfg);
    let mut eng = SpecEngine::new(cfg, k);
    let assigned: Vec<usize> = prompts.iter().map(|_| cache.alloc().unwrap()).collect();

    // prefill both planes over the same prompts (same slot indices)
    let mut ptoks = vec![0i32; slots * s];
    let mut lens = vec![0i32; slots];
    for (p, &slot) in prompts.iter().zip(&assigned) {
        ptoks[slot * s..slot * s + p.len()].copy_from_slice(p);
        lens[slot] = p.len() as i32;
    }
    let pshape = [slots, s];
    let sshape = [slots];
    let vshape = [slots, sw];
    let tout = {
        let feed = target
            .base_feed(Feed::new())
            .ints("tokens", &pshape, &ptoks)
            .ints("lens", &sshape, &lens);
        target.be.run("gpt-nano", "prefill", &feed).unwrap()
    };
    let dout = {
        let feed = draft
            .base_feed(Feed::new())
            .ints("tokens", &pshape, &ptoks)
            .ints("lens", &sshape, &lens);
        draft.be.run("gpt-nano", "prefill", &feed).unwrap()
    };
    for layer in 0..cache.n_layers() {
        let (k_, v_) = (tout.get(&format!("k::h{layer}")), tout.get(&format!("v::h{layer}")));
        let dc = eng.draft_cache();
        let (dk, dv) = (dout.get(&format!("k::h{layer}")), dout.get(&format!("v::h{layer}")));
        for &slot in &assigned {
            dc.adopt_prefill(slot, layer, dk, dv);
        }
        for &slot in &assigned {
            cache.adopt_prefill(slot, layer, k_, v_);
        }
    }
    for (p, &slot) in prompts.iter().zip(&assigned) {
        eng.admit(slot, p.len());
    }

    let mut pos: Vec<usize> = prompts.iter().map(Vec::len).collect();
    let mut last: Vec<i32> = assigned
        .iter()
        .map(|&slot| argmax(&tout.get("logits").data()[slot * vocab..(slot + 1) * vocab]))
        .collect();
    let mut results: Vec<Vec<i32>> = last.iter().map(|&t| vec![t]).collect();

    loop {
        let inputs: Vec<RoundInput> = assigned
            .iter()
            .enumerate()
            .filter(|&(r, _)| results[r].len() < steps && pos[r] + 1 < s)
            .map(|(r, &slot)| RoundInput { slot, pos: pos[r], last: last[r] })
            .collect();
        if inputs.is_empty() {
            break;
        }
        let (round, _stats) = eng
            .round(
                &mut cache,
                &inputs,
                |dc, toks, dpos| {
                    let mut feed = draft
                        .base_feed(Feed::new())
                        .ints("tokens", &sshape, toks)
                        .ints("pos", &sshape, dpos);
                    for layer in 0..dc.n_layers() {
                        feed = feed
                            .owned_key(format!("k::h{layer}"), &dc.k[layer])
                            .owned_key(format!("v::h{layer}"), &dc.v[layer]);
                    }
                    draft.be.run("gpt-nano", "decode_step", &feed)
                },
                |tc, toks, vpos, klen| {
                    let mut feed = target
                        .base_feed(Feed::new())
                        .ints("tokens", &vshape, toks)
                        .ints("pos", &sshape, vpos)
                        .ints("klen", &sshape, klen);
                    for layer in 0..tc.n_layers() {
                        feed = feed
                            .owned_key(format!("k::h{layer}"), &tc.k[layer])
                            .owned_key(format!("v::h{layer}"), &tc.v[layer]);
                    }
                    target.be.run("gpt-nano", "verify_step", &feed)
                },
            )
            .unwrap();
        for rr in &round {
            let r = assigned.iter().position(|&sl| sl == rr.slot).unwrap();
            assert!(!rr.committed.is_empty(), "a round always commits >= 1 token");
            results[r].extend_from_slice(&rr.committed);
            pos[r] += rr.committed.len();
            last[r] = *rr.committed.last().unwrap();
        }
    }
    for r in &mut results {
        r.truncate(steps);
    }
    results
}

/// Speculative decoding must be bitwise-invisible: the committed stream
/// equals target-only KV decoding (itself pinned against the full forward
/// pass above) for every draft and every K.
fn check_spec_parity(target: &Fixture, draft: &Fixture, k: usize, label: &str) {
    let prompts: Vec<Vec<i32>> = vec![
        vec![2, 7, 19, 4],
        vec![2, 33, 8],
        vec![2, 5, 90, 17, 61, 3],
    ];
    let steps = 10;
    let refs = target.kv_greedy(&prompts, steps);

    let single = spec_greedy(target, draft, &prompts[..1], steps, k);
    assert_eq!(single[0], refs[0], "single-stream spec decode diverged ({label})");

    let batched = spec_greedy(target, draft, &prompts, steps, k);
    for (i, (got, want)) in batched.iter().zip(&refs).enumerate() {
        assert_eq!(got, want, "spec stream {i} diverged under batching ({label})");
    }
}

#[test]
fn speculative_decode_matches_target_only_dense_draft() {
    // a perfect draft (identical weights): everything accepted, still exact
    let target = fixture(None);
    let draft = fixture(None);
    for k in [2, 4] {
        check_spec_parity(&target, &draft, k, &format!("dense draft, K={k}"));
    }
}

#[test]
fn speculative_decode_matches_target_only_sparse_draft() {
    // a 90%-pruned draft diverges often — rollbacks must be invisible
    let target = fixture(None);
    let draft = fixture(Some(0.9));
    for k in [2, 4] {
        check_spec_parity(&target, &draft, k, &format!("90% draft, K={k}"));
    }
}

#[test]
fn speculative_decode_matches_under_compressed_layouts() {
    // draft weights served from CSR and BSR compressed forms: the spec
    // round (and its rollbacks) stays bitwise-exact under layout dispatch
    let target = fixture(None);
    let csr = fixture_with_layout(Some(0.9), LayoutPolicy::Fixed(WeightLayout::Csr));
    check_spec_parity(&target, &csr, 4, "csr draft @ 90%, K=4");
    let bsr = fixture_with_layout(Some(0.9), LayoutPolicy::Fixed(WeightLayout::Bsr));
    check_spec_parity(&target, &bsr, 4, "bsr draft @ 90%, K=4");
}

fn check_parity_with(fx: &Fixture, label: &str) {
    let prompts: Vec<Vec<i32>> = vec![
        vec![2, 7, 19, 4],
        vec![2, 33, 8],
        vec![2, 5, 90, 17, 61, 3],
    ];
    let steps = 10;
    let refs: Vec<Vec<i32>> =
        prompts.iter().map(|p| fx.reference_greedy(p, steps)).collect();

    // single-stream decode matches the full-forward reference...
    let single = fx.kv_greedy(&prompts[..1], steps);
    assert_eq!(single[0], refs[0], "single-stream KV decode diverged ({label})");

    // ...and batched multi-slot decode matches every per-prompt reference
    let batched = fx.kv_greedy(&prompts, steps);
    for (i, (got, want)) in batched.iter().zip(&refs).enumerate() {
        assert_eq!(got, want, "stream {i} diverged under batching ({label})");
    }
}

fn check_parity(sparsity: Option<f64>) {
    let fx = fixture(sparsity);
    check_parity_with(&fx, &format!("sparsity {sparsity:?}"));
}

#[test]
fn greedy_kv_decode_matches_full_forward_dense() {
    check_parity(None);
}

#[test]
fn greedy_kv_decode_matches_full_forward_half_sparse() {
    check_parity(Some(0.5));
}

#[test]
fn greedy_kv_decode_matches_full_forward_csr_layout() {
    // the --layout csr serving path: every prunable linear compressed
    let fx = fixture_with_layout(Some(0.9), LayoutPolicy::Fixed(WeightLayout::Csr));
    assert_eq!(fx.sparse.forms.len(), fx.mm.prunable.len(), "all linears should be compressed");
    check_parity_with(&fx, "layout csr @ 90%");
}

#[test]
fn greedy_kv_decode_matches_full_forward_bsr_24() {
    // 2:4 masks compress into native 1x4 blocks; the decode path runs the
    // fused q/k/v kernel over the BSR forms — still bitwise vs full forward
    let fx = fixture_pattern(
        Some(Pattern::SemiStructured { n: 2, m: 4 }),
        LayoutPolicy::Fixed(WeightLayout::Bsr),
    );
    assert_eq!(fx.sparse.forms.len(), fx.mm.prunable.len(), "all linears should be compressed");
    for (n, f) in &fx.sparse.forms {
        assert_eq!(f.layout(), WeightLayout::Bsr, "{n} not BSR-routed");
    }
    check_parity_with(&fx, "layout bsr @ 2:4");
}

#[test]
fn greedy_kv_decode_matches_full_forward_bsr_90() {
    // unstructured masks fall back to 4x4 tiles; parity must still be exact
    let fx = fixture_with_layout(Some(0.9), LayoutPolicy::Fixed(WeightLayout::Bsr));
    assert_eq!(fx.sparse.forms.len(), fx.mm.prunable.len(), "all linears should be compressed");
    check_parity_with(&fx, "layout bsr @ 90%");
}

#[test]
fn greedy_kv_decode_matches_full_forward_auto_layout() {
    // auto routes 90%-sparse layers to CSR (0.9 >= default crossover 0.75)
    let fx = fixture_with_layout(Some(0.9), LayoutPolicy::Auto);
    assert!(!fx.sparse.forms.is_empty(), "auto should compress 90%-sparse layers");
    check_parity_with(&fx, "layout auto @ 90%");
}

#[test]
fn greedy_kv_decode_matches_full_forward_auto_24() {
    // without a crossover table, auto's fallback routes 2:4 masks to BSR —
    // and never to a quantised layout on this bitwise-pinned path
    let fx = fixture_pattern(Some(Pattern::SemiStructured { n: 2, m: 4 }), LayoutPolicy::Auto);
    assert!(
        fx.sparse.forms.values().any(|f| f.layout() == WeightLayout::Bsr),
        "auto should BSR-route 2:4 masks"
    );
    for (n, l) in &fx.sparse.layouts {
        assert!(!l.is_quantised(), "auto quantised {n}: {l:?}");
    }
    check_parity_with(&fx, "layout auto @ 2:4");
}

#[test]
fn prefill_logits_match_full_forward_bitwise_csr() {
    // same bitwise pin as the masked-layout test below, under CSR
    let fx = fixture_with_layout(Some(0.9), LayoutPolicy::Fixed(WeightLayout::Csr));
    prefill_bitwise_check(&fx);
}

#[test]
fn prefill_logits_match_full_forward_bitwise_bsr() {
    let fx = fixture_pattern(
        Some(Pattern::SemiStructured { n: 2, m: 4 }),
        LayoutPolicy::Fixed(WeightLayout::Bsr),
    );
    prefill_bitwise_check(&fx);
}

#[test]
fn prefill_logits_match_full_forward_bitwise() {
    let fx = fixture(Some(0.5));
    prefill_bitwise_check(&fx);
}

fn prefill_bitwise_check(fx: &Fixture) {
    let cfg = &fx.mm.cfg;
    let (slots, s, vocab) = (cfg.serve_slots, cfg.seq_len, cfg.vocab);
    let prompt = vec![2i32, 11, 47, 5, 9];

    // reference logits at the last prompt position (batch = 1)
    let params: BTreeMap<String, &Tensor> =
        fx.params.iter().map(|(k, v)| (k.clone(), v)).collect();
    let masks: BTreeMap<String, &Tensor> =
        fx.masks.iter().map(|(k, v)| (k.clone(), v)).collect();
    let gi = fx.graph_in(&params, &masks);
    let mut toks = vec![0i32; s];
    toks[..prompt.len()].copy_from_slice(&prompt);
    let tape = graph::forward(&gi, &toks, 1, s);
    let want = &tape.logits.data()[(prompt.len() - 1) * vocab..prompt.len() * vocab];

    // prefill logits for the same prompt in slot 0 of a full-width batch
    let mut ptoks = vec![0i32; slots * s];
    ptoks[..prompt.len()].copy_from_slice(&prompt);
    let mut lens = vec![0i32; slots];
    lens[0] = prompt.len() as i32;
    let pshape = [slots, s];
    let sshape = [slots];
    let feed = fx
        .base_feed(Feed::new())
        .ints("tokens", &pshape, &ptoks)
        .ints("lens", &sshape, &lens);
    let out = fx.be.run("gpt-nano", "prefill", &feed).unwrap();
    let got = &out.get("logits").data()[..vocab];
    for (a, b) in got.iter().zip(want) {
        assert_eq!(a.to_bits(), b.to_bits(), "prefill logits differ from forward");
    }
}
