//! The inline `--stages` grammar.
//!
//! ```text
//! spec    := elem ('|' elem)*
//! elem    := stage | fork | seeds | agg
//! stage   := name [ '(' arg (',' arg)* ')' ]
//! name    := pretrain | prune | retrain | reconstruct | merge | eval | export | spec
//! fork    := 'fork[' spec (';' spec)* ']'
//! seeds   := 'seeds(' n ')'
//! agg     := 'agg' [ '(' name ')' ]
//! ```
//!
//! Examples:
//!
//! ```text
//! prune(wanda,0.5)|retrain(masklora,100)|merge|eval
//! prune(magnitude,2:4)|reconstruct(full)|eval(ppl)|export(results/m.ptns)
//! fork[prune(magnitude,0.5);prune(magnitude,0.7)]|retrain(masklora)|merge|eval(ppl)
//! prune(magnitude,0.5)|eval(ppl)|seeds(3)|agg
//! ```
//!
//! Positional args mirror the JSON fields: `prune(criterion,sparsity)`,
//! `retrain(mode[,steps[,lr]])`, `reconstruct(mode[,steps[,lr]])`,
//! `eval([ppl|tasks])`, `export(path)`.  A leading `pretrain` is implied
//! when absent — every plan starts from the (cached) dense model.
//!
//! `spec(sparsity[,method])` is a macro, not a stage of its own: it expands
//! to the draft-production recipe `prune(method,sparsity)|retrain(masklora)|
//! merge` — the checkpoint a speculative-decoding draft is made of.  Chain
//! `|export(path)` and point `repro serve --draft path` at the result.
//!
//! **Fan-out forms** build a [`PlanGraph`] instead of a linear [`Plan`]:
//! `fork[...]` runs each `;`-separated branch off the current leaves (every
//! stage after the `]` extends *all* branches — nesting forks forms grids),
//! `seeds(n)` replicates the whole path so far across `n` consecutive
//! seeds, and `agg` reduces the current eval leaves into one mean±std row.
//! [`spec_is_graph`] tells the CLI which parser applies.

use crate::peft::Mode;
use crate::pruning::{Criterion, Pattern};

use super::graph::{GraphBuilder, PlanGraph};
use super::plan::{recon_mode_parse, Plan, Stage};

/// Split on `sep` at bracket depth zero (`[]` and `()` both nest), so fork
/// branches and stage arguments never leak separators.
fn split_top(s: &str, sep: char) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' | '(' => depth += 1,
            ']' | ')' => depth = depth.saturating_sub(1),
            c if c == sep && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts.into_iter().map(str::trim).filter(|p| !p.is_empty()).collect()
}

/// Does this spec use the fan-out forms (`fork[...]`, `seeds(n)`, `agg`)?
/// If so it parses with [`parse_graph`]; otherwise [`parse_plan`] keeps the
/// exact linear behaviour (and output) of the original grammar.
pub fn spec_is_graph(spec: &str) -> bool {
    split_top(spec, '|')
        .iter()
        .any(|e| is_agg_elem(e) || e.starts_with("fork[") || e.starts_with("seeds("))
}

fn is_agg_elem(e: &str) -> bool {
    e == "agg" || e == "aggregate" || e.starts_with("agg(") || e.starts_with("aggregate(")
}

/// Parse one `|`-separated stage spec into stages (no implied pretrain).
/// Macro elements (`spec(...)`) may expand to several stages each.
pub fn parse_stages(spec: &str) -> Result<Vec<Stage>, String> {
    let mut stages = Vec::new();
    for elem in split_top(spec, '|') {
        stages.extend(parse_elem(elem)?);
    }
    Ok(stages)
}

/// One grammar element → one or more stages.  `spec(sparsity[,method])`
/// expands to the draft-production recipe; everything else is a single
/// stage via [`parse_stage`].
fn parse_elem(s: &str) -> Result<Vec<Stage>, String> {
    if s == "spec" || s.starts_with("spec(") {
        return expand_spec_macro(s);
    }
    parse_stage(s).map(|st| vec![st])
}

/// `spec(sparsity[,method])` → `prune(method,sparsity)|retrain(masklora)|merge`.
fn expand_spec_macro(s: &str) -> Result<Vec<Stage>, String> {
    let args: Vec<&str> = match s.strip_prefix("spec(") {
        None => Vec::new(), // bare `spec`
        Some(rest) => rest
            .strip_suffix(')')
            .ok_or_else(|| format!("malformed stage {s:?} (unbalanced parentheses)"))?
            .split(',')
            .map(str::trim)
            .filter(|a| !a.is_empty())
            .collect(),
    };
    if args.len() > 2 {
        return Err(format!("spec: too many arguments in {s:?} (max 2)"));
    }
    let pattern = Pattern::parse(args.first().copied().unwrap_or("0.9"))?;
    let criterion = Criterion::parse(args.get(1).copied().unwrap_or("magnitude"))?;
    Ok(vec![
        Stage::Prune { criterion, pattern },
        Stage::Retrain { mode: Mode::MaskLora, steps: None, lr: None },
        Stage::Merge,
    ])
}

/// Parse a spec into a runnable [`Plan`], prepending `pretrain` if absent.
pub fn parse_plan(name: &str, spec: &str) -> Result<Plan, String> {
    let mut stages = parse_stages(spec)?;
    if stages.is_empty() {
        return Err("empty stage spec".to_string());
    }
    if stages[0] != Stage::Pretrain {
        stages.insert(0, Stage::Pretrain);
    }
    Ok(Plan { name: name.to_string(), stages })
}

/// Parse a fan-out spec into a [`PlanGraph`], prepending `pretrain` if the
/// first element isn't one.  Works for linear specs too (a single-path
/// graph), but the CLI routes those through [`parse_plan`] for byte-stable
/// linear reports.
pub fn parse_graph(name: &str, spec: &str) -> Result<PlanGraph, String> {
    let elems = split_top(spec, '|');
    if elems.is_empty() {
        return Err("empty stage spec".to_string());
    }
    let mut b = GraphBuilder::new(name);
    if elems[0] != "pretrain" {
        b = b.stage(Stage::Pretrain);
    }
    b = apply_seq(b, &elems)?;
    Ok(b.build())
}

/// Apply a `|`-sequence of elements to the builder's current frontier.
fn apply_seq(mut b: GraphBuilder, elems: &[&str]) -> Result<GraphBuilder, String> {
    for elem in elems {
        if let Some(body) = elem.strip_prefix("fork[") {
            let body = body
                .strip_suffix(']')
                .ok_or_else(|| format!("malformed fork {elem:?} (missing closing bracket)"))?;
            let branches = split_top(body, ';');
            if branches.is_empty() {
                return Err(format!("fork {elem:?} has no branches"));
            }
            let base = b.frontier();
            let mut next = Vec::new();
            for branch in branches {
                b.set_frontier(base.clone());
                b = apply_seq(b, &split_top(branch, '|'))?;
                next.extend(b.frontier());
            }
            b.set_frontier(next);
        } else if let Some(body) = elem.strip_prefix("seeds(") {
            let n: u64 = body
                .strip_suffix(')')
                .and_then(|v| v.trim().parse().ok())
                .ok_or_else(|| format!("seeds expects an integer, got {elem:?}"))?;
            b = b.try_replicate_seeds(n)?;
        } else if is_agg_elem(elem) {
            let body = elem
                .strip_prefix("aggregate(")
                .or_else(|| elem.strip_prefix("agg("))
                .and_then(|r| r.strip_suffix(')'));
            let name = match body {
                Some(n) if !n.trim().is_empty() => n.trim().to_string(),
                // auto-name from the first leaf it reduces — frontiers are
                // unique node sets, so distinct aggs never collide
                _ => format!(
                    "agg:{}",
                    b.frontier().first().cloned().unwrap_or_default()
                ),
            };
            b = b.aggregate(&name);
        } else {
            for st in parse_elem(elem)? {
                b = b.stage(st);
            }
        }
    }
    Ok(b)
}

fn parse_stage(s: &str) -> Result<Stage, String> {
    let (name, args) = match s.find('(') {
        None => (s, Vec::new()),
        Some(open) => {
            let Some(stripped) = s[open..].strip_prefix('(').and_then(|r| r.strip_suffix(')'))
            else {
                return Err(format!("malformed stage {s:?} (unbalanced parentheses)"));
            };
            let args: Vec<&str> = stripped
                .split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .collect();
            (&s[..open], args)
        }
    };
    let argc = |max: usize| -> Result<(), String> {
        if args.len() > max {
            Err(format!("{name}: too many arguments in {s:?} (max {max})"))
        } else {
            Ok(())
        }
    };
    match name {
        "pretrain" => {
            argc(0)?;
            Ok(Stage::Pretrain)
        }
        "prune" => {
            argc(2)?;
            let criterion = Criterion::parse(args.first().copied().unwrap_or("magnitude"))?;
            let pattern = Pattern::parse(args.get(1).copied().unwrap_or("0.5"))?;
            Ok(Stage::Prune { criterion, pattern })
        }
        "retrain" => {
            argc(3)?;
            let mode = Mode::parse(
                args.first()
                    .copied()
                    .ok_or_else(|| "retrain needs a mode, e.g. retrain(masklora)".to_string())?,
            )?;
            Ok(Stage::Retrain {
                mode,
                steps: parse_opt_u64(&args, 1, s)?,
                lr: parse_opt_f64(&args, 2, s)?,
            })
        }
        "reconstruct" => {
            argc(3)?;
            let mode = recon_mode_parse(args.first().copied().unwrap_or("masklora"))?;
            Ok(Stage::Reconstruct {
                mode,
                steps: parse_opt_u64(&args, 1, s)?,
                lr: parse_opt_f64(&args, 2, s)?,
            })
        }
        "merge" => {
            argc(0)?;
            Ok(Stage::Merge)
        }
        "eval" => {
            argc(1)?;
            let tasks = match args.first().copied() {
                None | Some("tasks") => true,
                Some("ppl") => false,
                Some(other) => return Err(format!("eval: unknown arg {other:?} (ppl|tasks)")),
            };
            Ok(Stage::Eval { tasks })
        }
        "export" => {
            argc(1)?;
            let path = args
                .first()
                .copied()
                .ok_or_else(|| "export needs a path, e.g. export(results/m.ptns)".to_string())?;
            Ok(Stage::Export { path: path.to_string() })
        }
        other => Err(format!(
            "unknown stage {other:?} (pretrain|prune|retrain|reconstruct|merge|eval|export|spec)"
        )),
    }
}

fn parse_opt_u64(args: &[&str], idx: usize, ctx: &str) -> Result<Option<u64>, String> {
    match args.get(idx) {
        None => Ok(None),
        Some(v) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|_| format!("{ctx}: expected an integer, got {v:?}")),
    }
}

fn parse_opt_f64(args: &[&str], idx: usize, ctx: &str) -> Result<Option<f64>, String> {
    match args.get(idx) {
        None => Ok(None),
        Some(v) => v
            .parse::<f64>()
            .map(Some)
            .map_err(|_| format!("{ctx}: expected a number, got {v:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_example_parses() {
        let p = parse_plan("inline", "prune(wanda,0.5)|retrain(masklora,100)|merge|eval").unwrap();
        assert_eq!(
            p.stages,
            vec![
                Stage::Pretrain,
                Stage::Prune { criterion: Criterion::Wanda, pattern: Pattern::Unstructured(0.5) },
                Stage::Retrain { mode: Mode::MaskLora, steps: Some(100), lr: None },
                Stage::Merge,
                Stage::Eval { tasks: true },
            ]
        );
        p.validate().unwrap();
    }

    #[test]
    fn defaults_and_explicit_pretrain() {
        let p = parse_plan("x", "pretrain|prune|eval(ppl)").unwrap();
        assert_eq!(p.stages.len(), 3);
        assert_eq!(
            p.stages[1],
            Stage::Prune {
                criterion: Criterion::Magnitude,
                pattern: Pattern::Unstructured(0.5)
            }
        );
        assert_eq!(p.stages[2], Stage::Eval { tasks: false });
    }

    #[test]
    fn nm_patterns_reconstruct_and_export() {
        let p = parse_plan(
            "x",
            "prune(sparsegpt,2:4)|reconstruct(full,20,0.002)|eval|export(out/m.ptns)",
        )
        .unwrap();
        assert_eq!(
            p.stages[1],
            Stage::Prune {
                criterion: Criterion::SparseGpt,
                pattern: Pattern::SemiStructured { n: 2, m: 4 }
            }
        );
        assert_eq!(
            p.stages[2],
            Stage::Reconstruct {
                mode: crate::coordinator::reconstruct::ReconMode::FullFt,
                steps: Some(20),
                lr: Some(2e-3),
            }
        );
        assert_eq!(p.stages[4], Stage::Export { path: "out/m.ptns".to_string() });
    }

    #[test]
    fn spec_macro_expands_to_draft_recipe() {
        let p = parse_plan("draft", "spec(0.9)|export(out/draft.ptns)").unwrap();
        assert_eq!(
            p.stages,
            vec![
                Stage::Pretrain,
                Stage::Prune {
                    criterion: Criterion::Magnitude,
                    pattern: Pattern::Unstructured(0.9)
                },
                Stage::Retrain { mode: Mode::MaskLora, steps: None, lr: None },
                Stage::Merge,
                Stage::Export { path: "out/draft.ptns".to_string() },
            ]
        );
        p.validate().unwrap();

        // explicit method, and the macro works inside graph specs too
        let p = parse_plan("d2", "spec(0.5,wanda)|eval(ppl)").unwrap();
        assert_eq!(
            p.stages[1],
            Stage::Prune { criterion: Criterion::Wanda, pattern: Pattern::Unstructured(0.5) }
        );
        let g = parse_graph("g", "spec(0.9)|eval(ppl)|seeds(2)").unwrap();
        g.validate().unwrap();
        // 2 seeds × (pretrain|prune|retrain|merge|eval)
        assert_eq!(g.stage_count(), 2 * 5);

        assert!(parse_stages("spec(0.9,magnitude,extra)").is_err());
        assert!(parse_stages("spec(nonsense)").is_err());
    }

    #[test]
    fn errors_are_clean() {
        assert!(parse_stages("prune(wanda,0.5").is_err());
        assert!(parse_stages("retrain").is_err());
        assert!(parse_stages("retrain(masklora,abc)").is_err());
        assert!(parse_stages("fly(me)").is_err());
        assert!(parse_stages("eval(everything)").is_err());
        assert!(parse_plan("x", " | ").is_err());
    }

    #[test]
    fn roundtrips_through_json() {
        let p = parse_plan("x", "prune(wanda,0.7)|retrain(scalelora,5,0.01)|merge|eval").unwrap();
        let p2 = Plan::from_text(&p.to_json().to_string()).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn graph_detection_is_precise() {
        assert!(!spec_is_graph("prune(wanda,0.5)|retrain(masklora)|merge|eval"));
        assert!(spec_is_graph("fork[prune(magnitude,0.5);prune(magnitude,0.7)]|eval(ppl)"));
        assert!(spec_is_graph("prune|eval(ppl)|seeds(3)"));
        assert!(spec_is_graph("prune|eval(ppl)|agg"));
        assert!(spec_is_graph("prune|eval(ppl)|agg(mean)"));
        assert!(spec_is_graph("prune|eval(ppl)|aggregate(mean)"));
        // a path argument containing the words is NOT a graph form
        assert!(!spec_is_graph("prune|eval(ppl)|export(out/fork[x].ptns)"));
    }

    #[test]
    fn fork_spec_builds_a_fan() {
        let g = parse_graph(
            "fan",
            "fork[prune(magnitude,0.5);prune(magnitude,0.7);prune(magnitude,0.9)]|eval(ppl)",
        )
        .unwrap();
        g.validate().unwrap();
        assert_eq!(g.roots().len(), 1, "one shared pretrain root");
        assert_eq!(g.stage_count(), 1 + 3 + 3);
        let root = g.roots()[0].name.clone();
        assert_eq!(g.children(&root).len(), 3);
        // each prune gets its own eval leaf
        assert_eq!(g.leaves().len(), 3);
        for leaf in g.leaves() {
            assert_eq!(leaf.label(), "eval(ppl)");
        }
    }

    #[test]
    fn fork_branches_may_be_chains_and_nest() {
        let g = parse_graph(
            "grid",
            "prune(magnitude,0.5)|fork[retrain(biases)|eval(ppl);retrain(masklora)|merge|eval(ppl)]",
        )
        .unwrap();
        g.validate().unwrap();
        // pretrain + prune + (retrain,eval) + (retrain,merge,eval)
        assert_eq!(g.stage_count(), 1 + 1 + 2 + 3);
        assert_eq!(g.leaves().len(), 2);

        // nested fork: 2 prunes × 2 modes = 4 leaves
        let g = parse_graph(
            "nested",
            "fork[prune(magnitude,0.5);prune(magnitude,0.7)]|fork[retrain(biases);retrain(ln)]|eval(ppl)",
        )
        .unwrap();
        g.validate().unwrap();
        assert_eq!(g.leaves().len(), 4);
    }

    #[test]
    fn seeds_and_agg_forms_parse_and_roundtrip() {
        let g = parse_graph("seeded", "prune(magnitude,0.5)|eval(ppl)|seeds(3)|agg(mean)").unwrap();
        g.validate().unwrap();
        assert_eq!(g.stage_count(), 3 * 3, "3 seeds × (pretrain|prune|eval)");
        assert_eq!(g.roots().len(), 3);
        let agg = g.get("mean").expect("named aggregate");
        match &agg.kind {
            crate::pipeline::NodeKind::Aggregate { over } => assert_eq!(over.len(), 3),
            other => panic!("expected aggregate, got {other:?}"),
        }
        // the long form names an aggregate too
        let g_long =
            parse_graph("seeded", "prune(magnitude,0.5)|eval(ppl)|seeds(3)|aggregate(mean)")
                .unwrap();
        assert_eq!(g, g_long);
        // graph JSON round-trip preserves the parsed structure exactly
        let g2 = PlanGraph::from_text(&g.to_json().to_string()).unwrap();
        assert_eq!(g, g2);
        let g3 = PlanGraph::from_text(&g.to_string_pretty()).unwrap();
        assert_eq!(g, g3);
    }

    #[test]
    fn graph_spec_errors_are_clean() {
        assert!(parse_graph("x", "fork[prune(magnitude,0.5)|eval(ppl)").is_err());
        assert!(parse_graph("x", "prune|eval(ppl)|seeds(zero)").is_err());
        assert!(parse_graph("x", "prune|eval(ppl)|seeds(0)").is_err());
        assert!(parse_graph("x", "fork[]|eval(ppl)").is_err());
        // nested seeds replication is rejected, not silently mangled
        assert!(parse_graph("x", "prune|eval(ppl)|seeds(2)|seeds(2)").is_err());
    }
}
