//! Index-compressed sparse weight layout (CSR) and its SpMM kernels.
//!
//! PERP keeps pruned networks pruned, but the masked kernels
//! (`linalg::matmul_nt_masked` / `matmul_masked`) still stream the full
//! dense `(m, k)` weight *and* mask buffers and branch per element — a
//! 90%-sparse layer pays almost the same memory traffic as a dense one.
//! [`CsrMatrix`] stores only the surviving weights
//! (row-ptr / col-idx / values, `nnz × 8 B + (m+1) × 4 B` vs the dense
//! `m·k × 4 B`), so the SpMM kernels touch exactly the kept entries:
//!
//! * [`spmm_nt`] — `a:(n,k) @ Wᵀ` with `W:(m,k)` compressed: the forward /
//!   serve-decode contraction;
//! * [`spmm`]    — `a:(n,m) @ W`  with `W:(m,k)` compressed: the
//!   backward-dx contraction.
//!
//! Both mirror the masked kernels' per-element accumulation order
//! (ascending inner index), so switching layouts never changes results
//! beyond dropped exact-zero products — greedy decode stays bit-identical
//! within a layout (pinned by `tests/decode_parity.rs`).
//!
//! Layout *selection* lives here too: [`WeightLayout`] names the three
//! execution strategies and [`LayoutPolicy`] resolves one per layer from
//! its measured sparsity ([`LayoutPolicy::Auto`] compresses layers at or
//! above the crossover sparsity, `PERP_CSR_CROSSOVER`, default 0.75 —
//! measured with `repro bench-kernels`).  [`SparseStore`] is the cached,
//! named collection the coordinator builds once at prune / merge /
//! load-checkpoint time and feeds to every subsequent execution.

use std::collections::BTreeMap;

use rayon::prelude::*;

use super::{pool, Tensor};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Layout selection.
// ---------------------------------------------------------------------------

/// How a masked linear's `x @ (W⊙M)ᵀ` contraction is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightLayout {
    /// Materialise `W⊙M` and run the dense kernel (the pre-fusion baseline).
    Dense,
    /// Fused masked kernels: read W and M, skip pruned entries per element.
    Masked,
    /// Compressed rows: touch only surviving weights ([`spmm_nt`]/[`spmm`]).
    Csr,
}

impl WeightLayout {
    pub fn name(&self) -> &'static str {
        match self {
            WeightLayout::Dense => "dense",
            WeightLayout::Masked => "masked",
            WeightLayout::Csr => "csr",
        }
    }
}

/// Per-layer layout choice: forced, or resolved from measured sparsity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutPolicy {
    /// Pick per layer: CSR at or above the crossover sparsity, fused masked
    /// kernels below it (they never lose to the materialising dense path).
    Auto,
    /// One layout for every layer (`--layout dense|masked|csr`).
    Fixed(WeightLayout),
}

impl LayoutPolicy {
    pub fn parse(s: &str) -> Result<LayoutPolicy, String> {
        match s {
            "auto" => Ok(LayoutPolicy::Auto),
            "dense" => Ok(LayoutPolicy::Fixed(WeightLayout::Dense)),
            "masked" => Ok(LayoutPolicy::Fixed(WeightLayout::Masked)),
            "csr" => Ok(LayoutPolicy::Fixed(WeightLayout::Csr)),
            other => Err(format!("unknown layout {other:?} (auto|dense|masked|csr)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LayoutPolicy::Auto => "auto",
            LayoutPolicy::Fixed(l) => l.name(),
        }
    }

    /// Sparsity at which CSR overtakes the fused masked kernel.  The default
    /// comes from `repro bench-kernels` on the runtime_micro GEMM shapes;
    /// `PERP_CSR_CROSSOVER` overrides it for other machines.
    pub fn csr_crossover() -> f64 {
        std::env::var("PERP_CSR_CROSSOVER")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|v| (0.0..=1.0).contains(v))
            .unwrap_or(0.75)
    }

    /// Resolve the layout for one layer from its measured sparsity.
    pub fn resolve(&self, sparsity: f64) -> WeightLayout {
        match self {
            LayoutPolicy::Fixed(l) => *l,
            LayoutPolicy::Auto => {
                if sparsity >= Self::csr_crossover() {
                    WeightLayout::Csr
                } else {
                    WeightLayout::Masked
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// CSR matrix.
// ---------------------------------------------------------------------------

/// Compressed-sparse-row form of a 2-D weight matrix, built once from
/// `W ⊙ M`.  Entries are the coordinates where the product is non-zero, in
/// row-major / ascending-column order — the same traversal order as the
/// masked kernels, which keeps cross-layout results aligned.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// `rows + 1` offsets into `col_idx`/`values`.
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Compress the non-zeros of `w ⊙ mask` (an all-ones mask therefore
    /// compresses the non-zeros of `w` itself — the checkpoint-serving case,
    /// where pruned weights carry their zeros in the values).
    pub fn from_dense_masked(w: &Tensor, mask: &Tensor) -> CsrMatrix {
        assert_eq!(w.shape(), mask.shape(), "mask must be shaped like w");
        let (m, k) = (w.rows(), w.cols());
        // row_ptr stores nnz as u32 and nnz <= m·k, so bound the product
        assert!(m * k <= u32::MAX as usize, "matrix too large for u32 CSR offsets");
        let (wd, md) = (w.data(), mask.data());
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..m {
            for j in 0..k {
                let v = wd[i * k + j] * md[i * k + j];
                if v != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        CsrMatrix { rows: m, cols: k, row_ptr, col_idx, values }
    }

    /// Decompress back to a dense `(rows, cols)` tensor (dropped entries
    /// come back as exact 0.0).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out[i * self.cols + c as usize] = v;
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.col_idx[lo..hi], &self.values[lo..hi])
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries *not* stored.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Compressed footprint: `nnz × 8 B + (rows + 1) × 4 B` (values +
    /// col-idx per entry, plus the row-pointer array).
    pub fn mem_bytes(&self) -> usize {
        self.nnz() * 8 + self.row_ptr.len() * 4
    }

    /// Dense footprint of the same matrix (`rows · cols × 4 B`).
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

// ---------------------------------------------------------------------------
// SpMM kernels.
// ---------------------------------------------------------------------------

/// Rows of `a` each rayon task owns in the tall-activation strategy.
const ROWS_PER_TASK: usize = 4;
/// Output columns per task in the single-row (decode) strategy.
const COLS_PER_TASK: usize = 64;

#[inline]
fn csr_dot(arow: &[f32], cols: &[u32], vals: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&c, &v) in cols.iter().zip(vals) {
        acc += arow[c as usize] * v;
    }
    acc
}

/// `a:(n,k) @ W:(m,k)ᵀ -> (n,m)` with `W` compressed — the forward /
/// decode contraction.  Only the `nnz` surviving weights are read, so the
/// weight-side memory traffic shrinks by `1 / (1 - sparsity)`.  Per output
/// element the accumulation order is ascending column index — identical to
/// `matmul_nt_masked`, so the two layouts agree bit-for-bit wherever no
/// stored weight is exactly zero.
pub fn spmm_nt(a: &Tensor, w: &CsrMatrix) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    assert_eq!(k, w.cols, "spmm_nt inner-dim mismatch {k} vs {}", w.cols);
    let m = w.rows;
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    if n == 1 {
        // one activation row (serve decode): parallelise over W rows instead
        out.par_chunks_mut(COLS_PER_TASK).enumerate().for_each(|(cj, chunk)| {
            let j0 = cj * COLS_PER_TASK;
            for (jj, o) in chunk.iter_mut().enumerate() {
                let (cols, vals) = w.row(j0 + jj);
                *o = csr_dot(ad, cols, vals);
            }
        });
    } else {
        out.par_chunks_mut(ROWS_PER_TASK * m).enumerate().for_each(|(ci, chunk)| {
            let i0 = ci * ROWS_PER_TASK;
            for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                for (j, o) in orow.iter_mut().enumerate() {
                    let (cols, vals) = w.row(j);
                    *o = csr_dot(arow, cols, vals);
                }
            }
        });
    }
    Tensor::new(&[n, m], out)
}

/// `a:(n,m) @ W:(m,k) -> (n,k)` with `W` compressed — the backward-dx
/// contraction.  Exact zeros of `a` are skipped (like `matmul`), and each
/// consumed `a` element scatters one compressed row; per output element
/// contributions arrive in ascending inner index, matching
/// `matmul_masked`'s order.
pub fn spmm(a: &Tensor, w: &CsrMatrix) -> Tensor {
    let (n, m) = (a.rows(), a.cols());
    assert_eq!(m, w.rows, "spmm inner-dim mismatch {m} vs {}", w.rows);
    let k = w.cols;
    let mut out = pool::zeroed(n * k);
    let ad = a.data();
    out.par_chunks_mut(ROWS_PER_TASK * k).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * ROWS_PER_TASK;
        for (ii, orow) in chunk.chunks_mut(k).enumerate() {
            let arow = &ad[(i0 + ii) * m..(i0 + ii + 1) * m];
            for (j, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let (cols, vals) = w.row(j);
                for (&c, &v) in cols.iter().zip(vals) {
                    orow[c as usize] += av * v;
                }
            }
        }
    });
    Tensor::new(&[n, k], out)
}

// ---------------------------------------------------------------------------
// Named collections: the coordinator-side cache and its borrowed view.
// ---------------------------------------------------------------------------

/// Cached sparse state for a model's prunable linears: one resolved
/// [`WeightLayout`] per weight, plus the [`CsrMatrix`] forms for the
/// CSR-routed ones.  Built once per weight/mask change (prune, merge,
/// checkpoint load) so steady-state train/serve loops never re-compress.
#[derive(Debug, Clone, Default)]
pub struct SparseStore {
    pub layouts: BTreeMap<String, WeightLayout>,
    pub csr: BTreeMap<String, CsrMatrix>,
}

impl SparseStore {
    /// Resolve a layout per layer from its measured `W⊙M` sparsity and
    /// compress the CSR-routed layers.
    pub fn build<'a>(
        policy: LayoutPolicy,
        layers: impl Iterator<Item = (String, &'a Tensor, &'a Tensor)>,
    ) -> SparseStore {
        let mut store = SparseStore::default();
        store.update(policy, layers);
        store
    }

    /// Re-resolve and recompress a subset of layers in place — the cheap
    /// path when only one block's weights/masks changed (layer-wise
    /// reconstruction); [`SparseStore::build`] is `update` over everything.
    pub fn update<'a>(
        &mut self,
        policy: LayoutPolicy,
        layers: impl Iterator<Item = (String, &'a Tensor, &'a Tensor)>,
    ) {
        for (name, w, mask) in layers {
            let layout = match policy {
                // fixed policies never read the sparsity — skip the scan
                LayoutPolicy::Fixed(l) => l,
                LayoutPolicy::Auto => {
                    let nnz = w
                        .data()
                        .iter()
                        .zip(mask.data())
                        .filter(|(&wv, &mv)| wv * mv != 0.0)
                        .count();
                    policy.resolve(1.0 - nnz as f64 / w.numel().max(1) as f64)
                }
            };
            if layout == WeightLayout::Csr {
                self.csr.insert(name.clone(), CsrMatrix::from_dense_masked(w, mask));
            } else {
                self.csr.remove(&name);
            }
            self.layouts.insert(name, layout);
        }
    }

    /// No layer deviates from the default fused-masked path.
    pub fn is_empty(&self) -> bool {
        self.layouts.values().all(|l| *l == WeightLayout::Masked)
    }

    pub fn has_csr(&self, name: &str) -> bool {
        self.csr.contains_key(name)
    }

    /// Total compressed bytes across layers (exported by the serve layer
    /// as the `perp_serve_csr_weight_bytes` gauge).
    pub fn csr_bytes(&self) -> usize {
        self.csr.values().map(CsrMatrix::mem_bytes).sum()
    }

    pub fn view(&self) -> SparseView<'_> {
        SparseView {
            layouts: self.layouts.clone(),
            csr: self.csr.iter().map(|(n, c)| (n.clone(), c)).collect(),
        }
    }
}

/// Borrowed per-execution view — what [`crate::runtime::Feed`] transports
/// and the native graph dispatches on.  An empty view means every linear
/// runs the fused masked kernels (the status quo).
#[derive(Debug, Default)]
pub struct SparseView<'a> {
    pub layouts: BTreeMap<String, WeightLayout>,
    pub csr: BTreeMap<String, &'a CsrMatrix>,
}

impl<'a> SparseView<'a> {
    /// Resolved layout for one weight; CSR only when the compressed form is
    /// actually present, so a stale routing can never panic the kernels.
    pub fn layout_of(&self, wname: &str) -> WeightLayout {
        if self.csr.contains_key(wname) {
            return WeightLayout::Csr;
        }
        match self.layouts.get(wname) {
            Some(WeightLayout::Dense) => WeightLayout::Dense,
            _ => WeightLayout::Masked,
        }
    }

    pub fn get_csr(&self, wname: &str) -> Option<&'a CsrMatrix> {
        self.csr.get(wname).copied()
    }
}

/// A binary mask with an exact number of zeros — benches and tests need
/// pinned sparsity levels, which thresholded gaussians only approximate.
pub fn random_mask(shape: &[usize], sparsity: f64, rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    let zeros = ((n as f64) * sparsity).round() as usize;
    let mut idx: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut idx);
    let mut data = vec![1.0f32; n];
    for &i in &idx[..zeros.min(n)] {
        data[i as usize] = 0.0;
    }
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;

    fn random_case(m: usize, k: usize, sparsity: f64, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mask = random_mask(&[m, k], sparsity, &mut rng);
        (w, mask)
    }

    #[test]
    fn roundtrip_matches_masked_product() {
        for (m, k, s) in [(1usize, 1usize, 0.0), (7, 13, 0.5), (33, 65, 0.99), (8, 8, 1.0)] {
            let (w, mask) = random_case(m, k, s, 3);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            assert_eq!(csr.to_dense(), w.hadamard(&mask), "{m}x{k}@{s}");
            assert_eq!(csr.sparsity(), 1.0 - csr.nnz() as f64 / (m * k) as f64);
        }
    }

    #[test]
    fn all_ones_mask_compresses_weight_zeros() {
        // checkpoint serving: zeros live in the weights, the mask is dense
        let w = Tensor::new(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let ones = Tensor::ones(&[2, 3]);
        let csr = CsrMatrix::from_dense_masked(&w, &ones);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), w);
    }

    #[test]
    fn memory_formula() {
        let (w, mask) = random_case(16, 32, 0.9, 5);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        assert_eq!(csr.mem_bytes(), csr.nnz() * 8 + (16 + 1) * 4);
        assert_eq!(csr.dense_bytes(), 16 * 32 * 4);
        assert!(csr.mem_bytes() < csr.dense_bytes());
    }

    #[test]
    fn spmm_nt_bitwise_matches_masked_kernel() {
        let mut rng = Rng::new(11);
        for (n, k, m, s) in
            [(1usize, 33usize, 17usize, 0.9), (5, 64, 31, 0.5), (9, 17, 65, 0.0), (4, 8, 8, 1.0)]
        {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            let got = spmm_nt(&a, &csr);
            let want = linalg::matmul_nt_masked(&a, &w, &mask);
            assert_eq!(got.shape(), want.shape());
            for (x, y) in got.data().iter().zip(want.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{n}x{k}x{m}@{s}");
            }
        }
    }

    #[test]
    fn spmm_matches_masked_backward() {
        let mut rng = Rng::new(13);
        for (n, m, k, s) in [(1usize, 17usize, 33usize, 0.9), (6, 31, 64, 0.5), (3, 8, 8, 1.0)] {
            let dy = Tensor::randn(&[n, m], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            let got = spmm(&dy, &csr);
            let want = linalg::matmul_masked(&dy, &w, &mask);
            assert!(got.allclose(&want, 1e-6, 1e-6), "{n}x{m}x{k}@{s}");
        }
    }

    #[test]
    fn empty_and_single_rows() {
        // row 0 fully pruned, single-row matrix, fully pruned matrix
        let w = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mask = Tensor::new(&[2, 3], vec![0.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        let a = Tensor::new(&[1, 3], vec![1.0, 1.0, 1.0]);
        assert_eq!(spmm_nt(&a, &csr).data(), &[0.0, 10.0]);

        let single = CsrMatrix::from_dense_masked(
            &Tensor::new(&[1, 3], vec![2.0, 0.0, 4.0]),
            &Tensor::ones(&[1, 3]),
        );
        assert_eq!(spmm_nt(&a, &single).data(), &[6.0]);
        assert_eq!(single.row(0).0, &[0, 2]);

        let dead = CsrMatrix::from_dense_masked(&w, &Tensor::zeros(&[2, 3]));
        assert_eq!(dead.nnz(), 0);
        assert_eq!(spmm_nt(&a, &dead).data(), &[0.0, 0.0]);
        assert_eq!(spmm(&Tensor::ones(&[2, 2]), &dead).data(), &[0.0; 6]);
    }

    #[test]
    fn policy_parse_and_resolve() {
        assert_eq!(LayoutPolicy::parse("auto").unwrap(), LayoutPolicy::Auto);
        assert_eq!(
            LayoutPolicy::parse("csr").unwrap(),
            LayoutPolicy::Fixed(WeightLayout::Csr)
        );
        assert!(LayoutPolicy::parse("coo").is_err());
        assert_eq!(LayoutPolicy::Auto.resolve(0.99), WeightLayout::Csr);
        assert_eq!(LayoutPolicy::Auto.resolve(0.0), WeightLayout::Masked);
        assert_eq!(
            LayoutPolicy::Fixed(WeightLayout::Dense).resolve(0.99),
            WeightLayout::Dense
        );
    }

    #[test]
    fn store_builds_csr_only_where_routed() {
        let mut rng = Rng::new(17);
        let dense_w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let sparse_w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let ones = Tensor::ones(&[8, 8]);
        let mask = random_mask(&[8, 8], 0.9, &mut rng);
        let layers = vec![
            ("a_w".to_string(), &dense_w, &ones),
            ("b_w".to_string(), &sparse_w, &mask),
        ];
        let store = SparseStore::build(LayoutPolicy::Auto, layers.into_iter());
        assert_eq!(store.layouts["a_w"], WeightLayout::Masked);
        assert_eq!(store.layouts["b_w"], WeightLayout::Csr);
        assert!(store.has_csr("b_w") && !store.has_csr("a_w"));
        assert!(!store.is_empty());
        assert!(store.csr_bytes() > 0);
        let view = store.view();
        assert_eq!(view.layout_of("a_w"), WeightLayout::Masked);
        assert_eq!(view.layout_of("b_w"), WeightLayout::Csr);
        assert_eq!(view.layout_of("unknown_w"), WeightLayout::Masked);
        assert!(view.get_csr("b_w").is_some());
    }

    #[test]
    fn store_update_rescans_only_named_layers_and_drops_stale_csr() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let sparse_mask = random_mask(&[8, 8], 0.9, &mut rng);
        let ones = Tensor::ones(&[8, 8]);
        let mut store = SparseStore::build(
            LayoutPolicy::Auto,
            vec![("a_w".to_string(), &w, &sparse_mask)].into_iter(),
        );
        assert!(store.has_csr("a_w"));
        // the layer went dense (e.g. reconstruction reset): CSR must go away
        store.update(LayoutPolicy::Auto, vec![("a_w".to_string(), &w, &ones)].into_iter());
        assert!(!store.has_csr("a_w"));
        assert_eq!(store.layouts["a_w"], WeightLayout::Masked);
        // and back to pruned: recompressed, other entries untouched
        store.update(
            LayoutPolicy::Auto,
            vec![("a_w".to_string(), &w, &sparse_mask)].into_iter(),
        );
        assert!(store.has_csr("a_w"));
        assert_eq!(store.csr["a_w"].to_dense(), w.hadamard(&sparse_mask));
    }

    #[test]
    fn random_mask_hits_exact_sparsity() {
        let mut rng = Rng::new(19);
        let m = random_mask(&[40, 50], 0.95, &mut rng);
        assert_eq!(m.count(|x| x == 0.0), 1900);
    }
}
