//! [`JobRunner`]: one daemon worker — an endless dequeue → execute loop
//! over a shared [`JobManager`].
//!
//! Each job runs through the ordinary plan-graph [`Executor`] with two
//! daemon-specific attachments: the manager's per-job cancel flag (so
//! shutdown and `POST /jobs/<id>/cancel` stop the walk after in-flight
//! nodes commit) and a node hook that persists per-node status to
//! `job.json` on every `Started`/`Finished` event — `GET /jobs/<id>` shows
//! live progress, and a kill at any point loses at most one event.
//!
//! Thread budget: a job with `jobs > 1` already splits the kernel budget
//! per in-flight node ([`crate::util::threads::acquire_share`] inside the
//! parallel walk); a serial (`jobs == 1`) job would otherwise fan every
//! kernel over the whole global pool, so the runner wraps its entire walk
//! in one budget share — N concurrent serial jobs split the budget N ways
//! instead of oversubscribing it.

use std::path::PathBuf;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::obs::counters::Registry;
use crate::pipeline::{Executor, Interrupted, NodeEvent, NodeHook};
use crate::runtime::Backend;
use crate::util::threads;

use super::queue::JobManager;
use super::store::{now_unix, JobStatus, NodeStatus};

/// One worker thread's context: backend + cache root + the shared queue.
pub struct JobRunner<'rt> {
    rt: &'rt dyn Backend,
    cache_dir: PathBuf,
    manager: Arc<JobManager>,
}

impl<'rt> JobRunner<'rt> {
    pub fn new(rt: &'rt dyn Backend, cache_dir: PathBuf, manager: Arc<JobManager>) -> Self {
        JobRunner { rt, cache_dir, manager }
    }

    /// Dequeue and execute jobs until shutdown drains the queue.
    pub fn run(&self) {
        while let Some((id, cancel)) = self.manager.dequeue() {
            if let Err(e) = self.execute(&id, &cancel) {
                crate::util::logging::progress(&format!("job {id}: runner error: {e:#}"));
                self.fail_job(&id, &e);
            }
            self.manager.finish(&id);
        }
    }

    /// Best-effort terminal state for a job whose runner errored outside
    /// the graph walk (store I/O around execute()).  Without this the
    /// record stays `running` on disk with no worker attached, invisible
    /// to everything until a restart's boot rescan.
    fn fail_job(&self, id: &str, err: &anyhow::Error) {
        let store = self.manager.store();
        let Ok(mut rec) = store.load(id) else { return };
        if rec.status.is_terminal() {
            return;
        }
        for n in rec.nodes.values_mut() {
            if n.status == NodeStatus::Running {
                n.status = NodeStatus::Failed;
            }
        }
        rec.status = JobStatus::Failed;
        rec.finished_unix = Some(now_unix());
        rec.error = Some(format!("runner error: {err:#}"));
        if store.save(&rec).is_ok() {
            store.clear_cancel(id);
            crate::count!("jobs.failed");
        }
    }

    /// Run one job to a terminal (or requeued-for-resume) state.
    fn execute(&self, id: &str, cancel: &Arc<AtomicBool>) -> Result<()> {
        let store = self.manager.store().clone();
        let mut rec = store.load(id)?;
        if rec.status.is_terminal() {
            return Ok(()); // cancelled after dequeue but before execution
        }
        let now = now_unix();
        let wait = now.saturating_sub(rec.queued_unix) as f64;
        Registry::global().observe("jobs.queue_wait_s", wait);
        rec.queue_wait_s = Some(wait);
        rec.status = JobStatus::Running;
        rec.started_unix = Some(now);
        rec.attempts += 1;
        rec.reset_running_nodes();
        store.save(&rec)?;

        // the node hook owns a shared copy of the record and persists it on
        // every event; save errors are swallowed (observability, not
        // semantics — the post-run save below is authoritative)
        let shared = Arc::new(Mutex::new(rec));
        let hook: NodeHook = {
            let shared = Arc::clone(&shared);
            let store = store.clone();
            Arc::new(move |ev: NodeEvent<'_>| {
                let mut r = shared.lock().unwrap_or_else(|p| p.into_inner());
                match ev {
                    NodeEvent::Started { name, .. } => {
                        if let Some(n) = r.nodes.get_mut(name) {
                            n.status = NodeStatus::Running;
                        }
                    }
                    NodeEvent::Finished(nrep) => {
                        if let Some(n) = r.nodes.get_mut(&nrep.name) {
                            n.status = NodeStatus::Done;
                            n.cache_hit = nrep.rep.cache_hit;
                            n.wall_s = Some(nrep.rep.wall_s);
                            n.key = nrep.rep.key.clone();
                        }
                    }
                }
                let _ = store.save(&r);
            })
        };

        let (spec_cfg, seed, exec_jobs) = {
            let r = shared.lock().unwrap_or_else(|p| p.into_inner());
            (r.spec.cfg.clone(), r.spec.seed, r.spec.jobs)
        };
        let exec = Executor::new(self.rt, spec_cfg, self.cache_dir.clone(), seed)
            .jobs(exec_jobs)
            .quiet(true)
            .cancel_flag(Arc::clone(cancel))
            .on_node(hook);
        let graph = shared.lock().unwrap_or_else(|p| p.into_inner()).spec.graph.clone();
        let execs0 = self.rt.exec_count();
        let t0 = Instant::now();
        let result = if exec_jobs <= 1 {
            // serial walk: hold one budget share for the whole job so
            // concurrent serial jobs split the kernel pool between them
            let share = threads::acquire_share();
            share.run(|| exec.run_graph(&graph))
        } else {
            exec.run_graph(&graph)
        };

        let mut rec = shared.lock().unwrap_or_else(|p| p.into_inner()).clone();
        rec.backend_execs += self.rt.exec_count().saturating_sub(execs0);
        rec.wall_s = Some(t0.elapsed().as_secs_f64());
        match result {
            Ok(report) => {
                rec.absorb_report(&report);
                rec.status = JobStatus::Done;
                rec.finished_unix = Some(now_unix());
                rec.error = None;
                crate::count!("jobs.done");
            }
            Err(e) if e.downcast_ref::<Interrupted>().is_some() => {
                rec.reset_running_nodes();
                if self.manager.was_cancelled(&rec.id) {
                    rec.status = JobStatus::Cancelled;
                    rec.finished_unix = Some(now_unix());
                    rec.error = Some(format!("{e:#}"));
                    crate::count!("jobs.cancelled");
                } else {
                    // daemon shutdown: back to the queue for the next boot
                    rec.status = JobStatus::Queued;
                    rec.queued_unix = now_unix();
                    rec.warnings.push(format!(
                        "attempt {} interrupted by daemon shutdown; requeued for resume",
                        rec.attempts
                    ));
                }
            }
            Err(e) => {
                for n in rec.nodes.values_mut() {
                    if n.status == NodeStatus::Running {
                        n.status = NodeStatus::Failed;
                    }
                }
                rec.status = JobStatus::Failed;
                rec.finished_unix = Some(now_unix());
                rec.error = Some(format!("{e:#}"));
                crate::count!("jobs.failed");
            }
        }
        store.save(&rec)?;
        if rec.status.is_terminal() {
            // the durable cancel marker (if any) has served its purpose —
            // terminal records never resume, so boot rescan ignores it;
            // just don't leave the stale file behind
            store.clear_cancel(&rec.id);
        }
        Ok(())
    }
}
