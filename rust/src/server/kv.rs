//! Per-stream KV-cache slots for the serving layer.
//!
//! The cache owns one (slots, H, S, dh) K and V tensor per layer — exactly
//! the `prefill` output / `decode_step` input planes — plus the slot
//! allocator the dynamic batcher draws from.  `prefill` results are adopted
//! wholesale (row `b` of the prefill batch is slot `b`); each `decode_step`
//! returns only the new K/V rows, which are written in place here, so the
//! backend itself stays stateless.

use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

pub struct KvCache {
    /// Per-layer K planes, each (slots, H, S, dh).
    pub k: Vec<Tensor>,
    /// Per-layer V planes, same shape.
    pub v: Vec<Tensor>,
    pub slots: usize,
    pub heads: usize,
    pub seq: usize,
    pub dh: usize,
    free: Vec<usize>,
}

impl KvCache {
    pub fn new(cfg: &ModelCfg) -> KvCache {
        let (slots, heads, seq, dh) = (cfg.serve_slots, cfg.n_heads, cfg.seq_len, cfg.d_head());
        let shape = [slots, heads, seq, dh];
        KvCache {
            k: (0..cfg.n_layers).map(|_| Tensor::zeros(&shape)).collect(),
            v: (0..cfg.n_layers).map(|_| Tensor::zeros(&shape)).collect(),
            slots,
            heads,
            seq,
            dh,
            free: (0..slots).rev().collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by live streams (the occupancy `/metrics` and
    /// the `serve.kv.occupied` histogram report).
    pub fn occupied(&self) -> usize {
        self.slots - self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        self.free.pop()
    }

    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.free.push(slot);
    }

    /// Adopt one stream's prefill result: copy slot row `slot` of the
    /// (slots, H, S, dh) prefill output planes into this cache.
    pub fn adopt_prefill(&mut self, slot: usize, layer: usize, k: &Tensor, v: &Tensor) {
        let n = self.heads * self.seq * self.dh;
        let span = slot * n..(slot + 1) * n;
        self.k[layer].data_mut()[span.clone()].copy_from_slice(&k.data()[span.clone()]);
        self.v[layer].data_mut()[span.clone()].copy_from_slice(&v.data()[span]);
    }

    /// Write one decode step's new K/V rows (the (slots, H, dh) `knew::`/
    /// `vnew::` outputs) at position `pos` of stream `slot`.
    pub fn write_new(&mut self, slot: usize, pos: usize, layer: usize, knew: &Tensor, vnew: &Tensor) {
        debug_assert!(pos < self.seq, "cache overflow: pos {pos} >= seq {}", self.seq);
        let (heads, seq, dh) = (self.heads, self.seq, self.dh);
        for hd in 0..heads {
            let src = slot * heads * dh + hd * dh;
            let dst = slot * heads * seq * dh + hd * seq * dh + pos * dh;
            self.k[layer].data_mut()[dst..dst + dh].copy_from_slice(&knew.data()[src..src + dh]);
            self.v[layer].data_mut()[dst..dst + dh].copy_from_slice(&vnew.data()[src..src + dh]);
        }
    }

    /// Write one verified position from a `verify_step` result: row `j` of
    /// the (slots, spec_width, H, dh) `knew::`/`vnew::` outputs lands at
    /// position `pos` of stream `slot`.
    #[allow(clippy::too_many_arguments)]
    pub fn write_spec(
        &mut self,
        slot: usize,
        pos: usize,
        layer: usize,
        j: usize,
        sw: usize,
        knew: &Tensor,
        vnew: &Tensor,
    ) {
        debug_assert!(pos < self.seq, "cache overflow: pos {pos} >= seq {}", self.seq);
        let (heads, seq, dh) = (self.heads, self.seq, self.dh);
        for hd in 0..heads {
            let src = ((slot * sw + j) * heads + hd) * dh;
            let dst = slot * heads * seq * dh + hd * seq * dh + pos * dh;
            self.k[layer].data_mut()[dst..dst + dh].copy_from_slice(&knew.data()[src..src + dh]);
            self.v[layer].data_mut()[dst..dst + dh].copy_from_slice(&vnew.data()[src..src + dh]);
        }
    }

    /// Roll stream `slot` back to `pos` valid tokens: zero every K/V row at
    /// positions `pos..seq` across all layers and heads.  After a rejected
    /// speculative proposal this leaves the slot bitwise-identical to never
    /// having drafted, because a fresh cache plane is all-zeros and
    /// `write_new`/`write_spec` only ever touch the row they commit.
    pub fn truncate_to(&mut self, slot: usize, pos: usize) {
        let (heads, seq, dh) = (self.heads, self.seq, self.dh);
        let pos = pos.min(seq);
        for layer in 0..self.n_layers() {
            for hd in 0..heads {
                let row0 = slot * heads * seq * dh + hd * seq * dh;
                let span = row0 + pos * dh..row0 + seq * dh;
                self.k[layer].data_mut()[span.clone()].fill(0.0);
                self.v[layer].data_mut()[span].fill(0.0);
            }
        }
    }

    /// Resident cache size: layers × 2 (K and V) × slots × H × S × dh × 4 B.
    pub fn bytes(&self) -> usize {
        kv_bytes_for(self.n_layers(), self.slots, self.heads, self.seq, self.dh)
    }
}

/// The KV-cache memory formula (documented in rust/README.md):
/// `n_layers * 2 * slots * n_heads * seq_len * d_head * 4` bytes
/// = `n_layers * 2 * slots * seq_len * d_model * 4` bytes.
pub fn kv_bytes_for(layers: usize, slots: usize, heads: usize, seq: usize, dh: usize) -> usize {
    layers * 2 * slots * heads * seq * dh * 4
}

/// Formula applied to a model config.
pub fn kv_bytes(cfg: &ModelCfg) -> usize {
    kv_bytes_for(cfg.n_layers, cfg.serve_slots, cfg.n_heads, cfg.seq_len, cfg.d_head())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelCfg;

    fn cache() -> KvCache {
        KvCache::new(&ModelCfg::builtin("gpt-nano").unwrap())
    }

    #[test]
    fn slot_allocator_roundtrips() {
        let mut c = cache();
        assert_eq!(c.free_slots(), c.slots);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(c.free_slots(), c.slots - 2);
        c.release(a);
        assert_eq!(c.free_slots(), c.slots - 1);
        for _ in 0..c.slots - 1 {
            assert!(c.alloc().is_some());
        }
        assert!(c.alloc().is_none());
    }

    #[test]
    fn writes_land_at_the_right_position() {
        let mut c = cache();
        let (slots, heads, seq, dh) = (c.slots, c.heads, c.seq, c.dh);
        let mut knew = Tensor::zeros(&[slots, heads, dh]);
        knew.data_mut()[2 * heads * dh] = 5.0; // slot 2, head 0, first lane
        let vnew = knew.clone();
        c.write_new(2, 3, 1, &knew, &vnew);
        let idx = 2 * heads * seq * dh + 3 * dh;
        assert_eq!(c.k[1].data()[idx], 5.0);
        assert_eq!(c.v[1].data()[idx], 5.0);
        // other layers and slots untouched
        assert_eq!(c.k[0].data()[idx], 0.0);
    }

    #[test]
    fn spec_writes_land_at_the_right_position() {
        let mut c = cache();
        let (slots, heads, seq, dh) = (c.slots, c.heads, c.seq, c.dh);
        let sw = 4;
        let mut knew = Tensor::zeros(&[slots, sw, heads, dh]);
        // slot 1, window row 2, head 1, first lane
        knew.data_mut()[((sw + 2) * heads + 1) * dh] = 7.0;
        let vnew = knew.clone();
        c.write_spec(1, 5, 0, 2, sw, &knew, &vnew);
        let idx = heads * seq * dh + seq * dh + 5 * dh;
        assert_eq!(c.k[0].data()[idx], 7.0);
        assert_eq!(c.v[0].data()[idx], 7.0);
    }

    /// The rollback guarantee the spec engine leans on: drafting rows past
    /// the accept point and truncating back is bitwise-identical to never
    /// having written them.
    #[test]
    fn truncate_restores_never_drafted_planes() {
        let mut c = cache();
        let (slots, heads, dh) = (c.slots, c.heads, c.dh);
        let mk = |seed: f32| {
            let mut t = Tensor::zeros(&[slots, heads, dh]);
            for (i, x) in t.data_mut().iter_mut().enumerate() {
                *x = seed + i as f32 * 0.25;
            }
            t
        };
        // commit positions 0..3 on slot 2 across every layer
        for layer in 0..c.n_layers() {
            for pos in 0..3 {
                let t = mk((layer * 10 + pos) as f32);
                c.write_new(2, pos, layer, &t, &t);
            }
        }
        let snap_k: Vec<Vec<f32>> = c.k.iter().map(|t| t.data().to_vec()).collect();
        let snap_v: Vec<Vec<f32>> = c.v.iter().map(|t| t.data().to_vec()).collect();
        // draft three more positions, then reject them all
        for layer in 0..c.n_layers() {
            for pos in 3..6 {
                let t = mk(-1.0 - (layer + pos) as f32);
                c.write_new(2, pos, layer, &t, &t);
            }
        }
        assert_ne!(snap_k[0], c.k[0].data());
        c.truncate_to(2, 3);
        for layer in 0..c.n_layers() {
            assert_eq!(snap_k[layer], c.k[layer].data(), "layer {layer} K diverged");
            assert_eq!(snap_v[layer], c.v[layer].data(), "layer {layer} V diverged");
        }
    }

    #[test]
    fn truncate_touches_only_its_slot() {
        let mut c = cache();
        let (slots, heads, dh) = (c.slots, c.heads, c.dh);
        let mut t = Tensor::zeros(&[slots, heads, dh]);
        t.data_mut().fill(3.0);
        for pos in 0..4 {
            c.write_new(0, pos, 0, &t, &t);
            c.write_new(1, pos, 0, &t, &t);
        }
        let snap = c.k[0].data().to_vec();
        c.truncate_to(1, 0); // wipe slot 1 entirely
        let n = heads * c.seq * dh;
        assert_eq!(&c.k[0].data()[..n], &snap[..n], "slot 0 must be untouched");
        assert!(c.k[0].data()[n..2 * n].iter().all(|&x| x == 0.0));
        // truncating past seq is a no-op rather than a panic
        c.truncate_to(0, c.seq + 5);
        assert_eq!(&c.k[0].data()[..n], &snap[..n]);
    }

    #[test]
    fn memory_formula_matches_planes() {
        let c = cache();
        let expect: usize =
            c.k.iter().chain(c.v.iter()).map(|t| t.numel() * 4).sum();
        assert_eq!(c.bytes(), expect);
        let cfg = ModelCfg::builtin("gpt-nano").unwrap();
        assert_eq!(kv_bytes(&cfg), expect);
    }
}
