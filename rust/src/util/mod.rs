//! Hand-rolled substrates for the offline build environment.
//!
//! Only the ~99 crates vendored from the reference image are available — no
//! serde / clap / criterion / proptest / rand.  Each replacement here is a
//! small, fully tested module with exactly the surface the rest of the crate
//! needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod threads;
