//! PJRT backend (cargo feature `pjrt`): load AOT HLO-text artifacts, compile
//! once, execute from the coordinator hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO *text* (jax ≥0.5 protos
//! carry 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns them).
//!
//! Executables are compiled lazily and cached per (model, name).  All
//! lowered graphs return tuples (`return_tuple=True`), unwrapped here.
//!
//! Builds without the real `xla` crate link the in-tree stub
//! (`rust/xla-stub`), which type-checks this module and fails at runtime
//! with a pointer to `--backend native`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{DType, ExecSpec, IoSpec, Manifest};
use crate::runtime::{Backend, Feed, Outputs};
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Literal conversion helpers.
// ---------------------------------------------------------------------------

pub fn f32_literal(t: &Tensor) -> Result<xla::Literal> {
    let mut bytes = Vec::with_capacity(t.numel() * 4);
    for &x in t.data() {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("creating f32 literal: {e:?}"))
}

pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("creating i32 literal: {e:?}"))
}

pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v: Vec<f32> = lit
        .to_vec()
        .map_err(|e| anyhow::anyhow!("literal -> f32 vec: {e:?}"))?;
    Ok(Tensor::new(shape, v))
}

/// Resolve one declared input from the feed into a device literal.
fn resolve_literal(feed: &Feed, spec: &IoSpec) -> Result<xla::Literal> {
    match spec.dtype {
        DType::I32 => {
            let (shape, data) = feed
                .get_ints(&spec.name)
                .with_context(|| format!("missing i32 input {:?}", spec.name))?;
            if shape != &spec.shape[..] {
                bail!("input {:?}: shape {shape:?} != spec {:?}", spec.name, spec.shape);
            }
            i32_literal(shape, data)
        }
        DType::F32 => {
            let t = feed
                .get_tensor(&spec.name)
                .with_context(|| format!("missing f32 input {:?}", spec.name))?;
            if t.shape() != &spec.shape[..] {
                bail!(
                    "input {:?}: tensor shape {:?} != spec {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
            f32_literal(t)
        }
    }
}

// ---------------------------------------------------------------------------
// Executable + backend.
// ---------------------------------------------------------------------------

pub struct Executable {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with a [`Feed`]; returns outputs as named host tensors.
    pub fn run(&self, feed: &Feed) -> Result<Outputs> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for spec in &self.spec.inputs {
            literals.push(
                resolve_literal(feed, spec)
                    .with_context(|| format!("feeding executable {:?}", self.spec.name))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {:?}: {e:?}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {:?}: {e:?}", self.spec.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {:?}: {e:?}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{:?}: {} outputs from device, {} in manifest",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut values = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            values.push((ospec.name.clone(), literal_to_tensor(lit, &ospec.shape)?));
        }
        Ok(Outputs { values })
    }
}

/// PJRT client + compiled-executable cache for one artifacts directory.
pub struct PjrtBackend {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<(String, String), Arc<Executable>>>,
    exec_count: AtomicU64,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(PjrtBackend {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            exec_count: AtomicU64::new(0),
        })
    }

    /// Compile (or fetch from cache) one executable of one model.
    pub fn load(&self, model: &str, exec: &str) -> Result<Arc<Executable>> {
        let key = (model.to_string(), exec.to_string());
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let mm = self.manifest.model(model)?;
        let spec = mm.exec(exec)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {exec:?}: {e:?}"))?;
        let wrapped = Arc::new(Executable { spec, exe });
        self.cache.lock().unwrap().insert(key, wrapped.clone());
        Ok(wrapped)
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prepare(&self, model: &str, exec: &str) -> Result<()> {
        self.load(model, exec).map(|_| ())
    }

    fn run(&self, model: &str, exec: &str, feed: &Feed) -> Result<Outputs> {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        self.load(model, exec)?.run(feed)
    }

    fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
