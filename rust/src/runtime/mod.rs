//! PJRT runtime bridge: load AOT HLO-text artifacts, compile once, execute
//! from the coordinator hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Interchange is HLO *text* (jax ≥0.5 protos
//! carry 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns them).
//!
//! Executables are compiled lazily and cached per (model, name).  All
//! lowered graphs return tuples (`return_tuple=True`), unwrapped here.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

pub use manifest::{DType, ExecSpec, IoSpec, Manifest, ModelCfg, ModelManifest};

use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Literal conversion helpers.
// ---------------------------------------------------------------------------

pub fn f32_literal(t: &Tensor) -> Result<xla::Literal> {
    let mut bytes = Vec::with_capacity(t.numel() * 4);
    for &x in t.data() {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        &bytes,
    )
    .map_err(|e| anyhow::anyhow!("creating f32 literal: {e:?}"))
}

pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, &bytes)
        .map_err(|e| anyhow::anyhow!("creating i32 literal: {e:?}"))
}

pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let v: Vec<f32> = lit
        .to_vec()
        .map_err(|e| anyhow::anyhow!("literal -> f32 vec: {e:?}"))?;
    Ok(Tensor::new(shape, v))
}

// ---------------------------------------------------------------------------
// Feed: named tensors for one execution.
// ---------------------------------------------------------------------------

/// Input values for one execution, resolved by manifest input name.
///
/// The coordinator layers register providers by prefix (`p::`, `m::`, ...)
/// through [`Feed::provider`]; one-off tensors (tokens, scalars) go in via
/// [`Feed::tensor`] / [`Feed::ints`] / [`Feed::scalar`].
#[derive(Default)]
pub struct Feed<'a> {
    tensors: HashMap<String, &'a Tensor>,
    owned: HashMap<String, Tensor>,
    ints: HashMap<String, (&'a [usize], &'a [i32])>,
    providers: Vec<&'a dyn Fn(&str) -> Option<&'a Tensor>>,
}

impl<'a> Feed<'a> {
    pub fn new() -> Feed<'a> {
        Feed::default()
    }
    pub fn tensor(mut self, name: &str, t: &'a Tensor) -> Self {
        self.tensors.insert(name.to_string(), t);
        self
    }
    /// Borrow with an owned key (hot loops that format names per step).
    pub fn owned_key(mut self, name: String, t: &'a Tensor) -> Self {
        self.tensors.insert(name, t);
        self
    }
    pub fn owned(mut self, name: &str, t: Tensor) -> Self {
        self.owned.insert(name.to_string(), t);
        self
    }
    pub fn scalar(self, name: &str, v: f32) -> Self {
        self.owned(name, Tensor::scalar(v))
    }
    pub fn ints(mut self, name: &str, shape: &'a [usize], data: &'a [i32]) -> Self {
        self.ints.insert(name.to_string(), (shape, data));
        self
    }
    /// Register a fallback resolver (e.g. ParamStore lookup for `p::*`).
    pub fn provider(mut self, f: &'a dyn Fn(&str) -> Option<&'a Tensor>) -> Self {
        self.providers.push(f);
        self
    }

    fn resolve(&self, spec: &IoSpec) -> Result<xla::Literal> {
        match spec.dtype {
            DType::I32 => {
                let (shape, data) = self
                    .ints
                    .get(&spec.name)
                    .with_context(|| format!("missing i32 input {:?}", spec.name))?;
                if *shape != &spec.shape[..] {
                    bail!("input {:?}: shape {shape:?} != spec {:?}", spec.name, spec.shape);
                }
                i32_literal(shape, data)
            }
            DType::F32 => {
                let t: &Tensor = if let Some(t) = self.tensors.get(&spec.name) {
                    t
                } else if let Some(t) = self.owned.get(&spec.name) {
                    t
                } else {
                    self.providers
                        .iter()
                        .find_map(|p| p(&spec.name))
                        .with_context(|| format!("missing f32 input {:?}", spec.name))?
                };
                if t.shape() != &spec.shape[..] {
                    bail!(
                        "input {:?}: tensor shape {:?} != spec {:?}",
                        spec.name,
                        t.shape(),
                        spec.shape
                    );
                }
                f32_literal(t)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Outputs: named tensors from one execution.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Outputs {
    pub values: Vec<(String, Tensor)>,
}

impl Outputs {
    pub fn get(&self, name: &str) -> &Tensor {
        &self
            .values
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output {name:?}"))
            .1
    }
    pub fn take(&mut self, name: &str) -> Tensor {
        let idx = self
            .values
            .iter()
            .position(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("no output {name:?}"));
        self.values.swap_remove(idx).1
    }
    pub fn scalar(&self, name: &str) -> f32 {
        self.get(name).data()[0]
    }
    /// Drain outputs whose name starts with `prefix`, stripping it.
    pub fn drain_prefix(&mut self, prefix: &str) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        let mut rest = Vec::new();
        for (n, t) in self.values.drain(..) {
            if let Some(stripped) = n.strip_prefix(prefix) {
                out.push((stripped.to_string(), t));
            } else {
                rest.push((n, t));
            }
        }
        self.values = rest;
        out
    }
}

// ---------------------------------------------------------------------------
// Executable + Runtime.
// ---------------------------------------------------------------------------

pub struct Executable {
    pub spec: ExecSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with a [`Feed`]; returns outputs as named host tensors.
    pub fn run(&self, feed: &Feed) -> Result<Outputs> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for spec in &self.spec.inputs {
            literals.push(
                feed.resolve(spec)
                    .with_context(|| format!("feeding executable {:?}", self.spec.name))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {:?}: {e:?}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {:?}: {e:?}", self.spec.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result of {:?}: {e:?}", self.spec.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{:?}: {} outputs from device, {} in manifest",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut values = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            values.push((ospec.name.clone(), literal_to_tensor(lit, &ospec.shape)?));
        }
        Ok(Outputs { values })
    }
}

/// PJRT client + compiled-executable cache for one artifacts directory.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<(String, String), Rc<Executable>>>,
    /// executions performed (metrics)
    pub exec_count: RefCell<u64>,
}

impl Runtime {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.manifest.model(name)
    }

    /// Compile (or fetch from cache) one executable of one model.
    pub fn load(&self, model: &str, exec: &str) -> Result<Rc<Executable>> {
        let key = (model.to_string(), exec.to_string());
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let mm = self.manifest.model(model)?;
        let spec = mm.exec(exec)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {exec:?}: {e:?}"))?;
        let wrapped = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(key, wrapped.clone());
        Ok(wrapped)
    }

    /// Convenience: load + run in one call.
    pub fn run(&self, model: &str, exec: &str, feed: &Feed) -> Result<Outputs> {
        *self.exec_count.borrow_mut() += 1;
        self.load(model, exec)?.run(feed)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Default artifacts directory: `$PERP_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("PERP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
