//! Rayon-parallel host kernels for the native backend.
//!
//! Each function is the rust port of the corresponding oracle in
//! `python/compile/kernels/ref.py` (the semantic spec the Pallas kernels are
//! tested against): tanh-approximate GELU, LayerNorm/RMSNorm with
//! biased variance, causal softmax attention, decoupled AdamW and the
//! next-token cross-entropy / likelihood-ranking heads.  Golden-fixture tests
//! in `rust/tests/native_kernels.rs` pin these against jax outputs.

use rayon::prelude::*;

use crate::tensor::{linalg, pool, Tensor};

pub const NORM_EPS: f32 = 1e-5;
/// AdamW defaults mirrored from ref.adamw (wd = 0 in every train graph).
pub const ADAM_BETA1: f32 = 0.9;
pub const ADAM_BETA2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

// ---------------------------------------------------------------------------
// Elementwise.
// ---------------------------------------------------------------------------

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const GELU_A: f32 = 0.044715;

/// Tanh-approximate GELU (jax.nn.gelu's default).
pub fn gelu(x: &Tensor) -> Tensor {
    x.map(|v| {
        let t = (GELU_C * (v + GELU_A * v * v * v)).tanh();
        0.5 * v * (1.0 + t)
    })
}

/// VJP of [`gelu`] at pre-activation `x`: dy ⊙ gelu'(x).
pub fn gelu_vjp(x: &Tensor, dy: &Tensor) -> Tensor {
    x.zip(dy, |v, g| {
        let inner = GELU_C * (v + GELU_A * v * v * v);
        let t = inner.tanh();
        let dinner = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
        g * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * dinner)
    })
}

/// y[i, :] += b — the linear bias broadcast.
pub fn add_bias(y: &mut Tensor, b: &Tensor) {
    let m = b.numel();
    let bd = b.data().to_vec();
    y.data_mut().par_chunks_mut(m).for_each(|row| {
        for (o, &bv) in row.iter_mut().zip(&bd) {
            *o += bv;
        }
    });
}

/// Column sums of a (n, m) matrix — the bias gradient.
pub fn col_sums(dy: &Tensor) -> Tensor {
    let (n, m) = (dy.rows(), dy.cols());
    let mut out = vec![0.0f64; m];
    let d = dy.data();
    for i in 0..n {
        for (o, &v) in out.iter_mut().zip(&d[i * m..(i + 1) * m]) {
            *o += v as f64;
        }
    }
    Tensor::new(&[m], out.into_iter().map(|x| x as f32).collect())
}

// ---------------------------------------------------------------------------
// Normalisation (forward + VJP).  Saved state mirrors what the backward pass
// needs: LayerNorm keeps x̂ and 1/σ, RMSNorm keeps the raw input and 1/rms.
// ---------------------------------------------------------------------------

pub struct NormCache {
    /// LayerNorm: x̂ (normalised, pre-scale).  RMSNorm: the raw input x.
    pub saved: Tensor,
    /// Per-row 1/σ (LayerNorm) or 1/rms (RMSNorm).
    pub inv: Vec<f32>,
}

impl NormCache {
    /// Return the cached buffers to the thread-local pool.
    pub fn recycle(self) {
        pool::recycle(self.saved);
        pool::give(self.inv);
    }
}

pub fn layernorm_fwd(x: &Tensor, scale: &Tensor, bias: &Tensor) -> (Tensor, NormCache) {
    let (n, d) = (x.rows(), x.cols());
    let mut y = pool::zeroed(n * d);
    let mut xhat = pool::zeroed(n * d);
    let mut inv = pool::zeroed(n);
    let (sd, bd) = (scale.data(), bias.data());
    y.par_chunks_mut(d)
        .zip(xhat.par_chunks_mut(d))
        .zip(inv.par_iter_mut())
        .enumerate()
        .for_each(|(i, ((yrow, xrow), invi))| {
            let row = &x.data()[i * d..(i + 1) * d];
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            let istd = 1.0 / (var + NORM_EPS).sqrt();
            *invi = istd;
            for j in 0..d {
                let h = (row[j] - mu) * istd;
                xrow[j] = h;
                yrow[j] = h * sd[j] + bd[j];
            }
        });
    (
        Tensor::new(&[n, d], y),
        NormCache { saved: Tensor::new(&[n, d], xhat), inv },
    )
}

/// Returns (dx, Some((dscale, dbias)) when `param_grads`).  The reductions
/// are skipped entirely for retraining subsets that freeze the norms.
pub fn layernorm_bwd(
    cache: &NormCache,
    scale: &Tensor,
    dy: &Tensor,
    param_grads: bool,
) -> (Tensor, Option<(Tensor, Tensor)>) {
    let (n, d) = (dy.rows(), dy.cols());
    let sd = scale.data();
    let xh = cache.saved.data();
    let mut dx = pool::zeroed(n * d);
    dx.par_chunks_mut(d).enumerate().for_each(|(i, dxrow)| {
        let dyrow = &dy.data()[i * d..(i + 1) * d];
        let xrow = &xh[i * d..(i + 1) * d];
        let istd = cache.inv[i];
        let mut mg = 0.0f32; // mean of g = dy * scale
        let mut mgx = 0.0f32; // mean of g * x̂
        for j in 0..d {
            let g = dyrow[j] * sd[j];
            mg += g;
            mgx += g * xrow[j];
        }
        mg /= d as f32;
        mgx /= d as f32;
        for j in 0..d {
            let g = dyrow[j] * sd[j];
            dxrow[j] = istd * (g - mg - xrow[j] * mgx);
        }
    });
    let dx = Tensor::new(&[n, d], dx);
    if !param_grads {
        return (dx, None);
    }
    // parameter grads (reduced over rows, f64 accumulation)
    let mut dscale = vec![0.0f64; d];
    let mut dbias = vec![0.0f64; d];
    for i in 0..n {
        let dyrow = &dy.data()[i * d..(i + 1) * d];
        let xrow = &xh[i * d..(i + 1) * d];
        for j in 0..d {
            dscale[j] += (dyrow[j] * xrow[j]) as f64;
            dbias[j] += dyrow[j] as f64;
        }
    }
    (
        dx,
        Some((
            Tensor::new(&[d], dscale.into_iter().map(|x| x as f32).collect()),
            Tensor::new(&[d], dbias.into_iter().map(|x| x as f32).collect()),
        )),
    )
}

pub fn rmsnorm_fwd(x: &Tensor, scale: &Tensor) -> (Tensor, NormCache) {
    let (n, d) = (x.rows(), x.cols());
    let mut y = pool::zeroed(n * d);
    let mut inv = pool::zeroed(n);
    let sd = scale.data();
    y.par_chunks_mut(d).zip(inv.par_iter_mut()).enumerate().for_each(|(i, (yrow, invi))| {
        let row = &x.data()[i * d..(i + 1) * d];
        let ms = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + NORM_EPS).sqrt();
        *invi = r;
        for j in 0..d {
            yrow[j] = row[j] * r * sd[j];
        }
    });
    (Tensor::new(&[n, d], y), NormCache { saved: x.clone(), inv })
}

/// Returns (dx, Some(dscale) when `param_grads`).
pub fn rmsnorm_bwd(
    cache: &NormCache,
    scale: &Tensor,
    dy: &Tensor,
    param_grads: bool,
) -> (Tensor, Option<Tensor>) {
    let (n, d) = (dy.rows(), dy.cols());
    let sd = scale.data();
    let xd = cache.saved.data();
    let mut dx = pool::zeroed(n * d);
    dx.par_chunks_mut(d).enumerate().for_each(|(i, dxrow)| {
        let dyrow = &dy.data()[i * d..(i + 1) * d];
        let xrow = &xd[i * d..(i + 1) * d];
        let r = cache.inv[i];
        let mut gx = 0.0f32; // Σ dy·scale·x
        for j in 0..d {
            gx += dyrow[j] * sd[j] * xrow[j];
        }
        let coef = gx * r * r * r / d as f32;
        for j in 0..d {
            dxrow[j] = dyrow[j] * sd[j] * r - xrow[j] * coef;
        }
    });
    let dx = Tensor::new(&[n, d], dx);
    if !param_grads {
        return (dx, None);
    }
    let mut dscale = vec![0.0f64; d];
    for i in 0..n {
        let dyrow = &dy.data()[i * d..(i + 1) * d];
        let xrow = &xd[i * d..(i + 1) * d];
        let r = cache.inv[i];
        for j in 0..d {
            dscale[j] += (dyrow[j] * xrow[j] * r) as f64;
        }
    }
    (
        dx,
        Some(Tensor::new(&[d], dscale.into_iter().map(|x| x as f32).collect())),
    )
}

// ---------------------------------------------------------------------------
// Head split/merge: (B*S, d) <-> (B, H, S, dh).
// ---------------------------------------------------------------------------

pub fn split_heads(x: &Tensor, b: usize, s: usize, h: usize, dh: usize) -> Tensor {
    let d = h * dh;
    assert_eq!(x.shape(), &[b * s, d]);
    let xd = x.data();
    let mut out = pool::zeroed(b * h * s * dh);
    out.par_chunks_mut(s * dh).enumerate().for_each(|(bh, chunk)| {
        let (bi, hi) = (bh / h, bh % h);
        for si in 0..s {
            let src = &xd[(bi * s + si) * d + hi * dh..(bi * s + si) * d + (hi + 1) * dh];
            chunk[si * dh..(si + 1) * dh].copy_from_slice(src);
        }
    });
    Tensor::new(&[b, h, s, dh], out)
}

pub fn merge_heads(x: &Tensor, b: usize, s: usize, h: usize, dh: usize) -> Tensor {
    let d = h * dh;
    assert_eq!(x.shape(), &[b, h, s, dh]);
    let xd = x.data();
    let mut out = pool::zeroed(b * s * d);
    out.par_chunks_mut(d).enumerate().for_each(|(bs, row)| {
        let (bi, si) = (bs / s, bs % s);
        for hi in 0..h {
            let src = &xd[((bi * h + hi) * s + si) * dh..((bi * h + hi) * s + si + 1) * dh];
            row[hi * dh..(hi + 1) * dh].copy_from_slice(src);
        }
    });
    Tensor::new(&[b * s, d], out)
}

// ---------------------------------------------------------------------------
// Causal softmax attention (forward + VJP), parallel over (batch, head).
// ---------------------------------------------------------------------------

/// q, k, v: (B, H, S, dh).  Returns (output (B, H, S, dh), probs (B, H, S, S)).
pub fn attention_fwd(q: &Tensor, k: &Tensor, v: &Tensor) -> (Tensor, Tensor) {
    crate::count!("ops.attention_fwd");
    let (b, h, s, dh) = dims4(q);
    assert_eq!(k.shape(), q.shape());
    assert_eq!(v.shape(), q.shape());
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = pool::zeroed(b * h * s * dh);
    let mut probs = pool::zeroed(b * h * s * s);
    out.par_chunks_mut(s * dh)
        .zip(probs.par_chunks_mut(s * s))
        .enumerate()
        .for_each(|(bh, (ochunk, pchunk))| {
            let base = bh * s * dh;
            let qd = &q.data()[base..base + s * dh];
            let kd = &k.data()[base..base + s * dh];
            let vd = &v.data()[base..base + s * dh];
            let mut row = vec![0.0f32; s];
            for i in 0..s {
                let qi = &qd[i * dh..(i + 1) * dh];
                let mut mx = f32::NEG_INFINITY;
                for (j, rj) in row.iter_mut().enumerate().take(i + 1) {
                    let kj = &kd[j * dh..(j + 1) * dh];
                    let dot: f32 = qi.iter().zip(kj).map(|(&a, &c)| a * c).sum();
                    *rj = dot * scale;
                    mx = mx.max(*rj);
                }
                let mut denom = 0.0f32;
                for rj in row.iter_mut().take(i + 1) {
                    *rj = (*rj - mx).exp();
                    denom += *rj;
                }
                let prow = &mut pchunk[i * s..(i + 1) * s];
                let orow = &mut ochunk[i * dh..(i + 1) * dh];
                for j in 0..=i {
                    let p = row[j] / denom;
                    prow[j] = p;
                    let vj = &vd[j * dh..(j + 1) * dh];
                    for (o, &vv) in orow.iter_mut().zip(vj) {
                        *o += p * vv;
                    }
                }
            }
        });
    (
        Tensor::new(&[b, h, s, dh], out),
        Tensor::new(&[b, h, s, s], probs),
    )
}

/// VJP of [`attention_fwd`].  Returns (dq, dk, dv), each (B, H, S, dh).
pub fn attention_bwd(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (b, h, s, dh) = dims4(q);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = pool::zeroed(b * h * s * dh);
    let mut dk = pool::zeroed(b * h * s * dh);
    let mut dv = pool::zeroed(b * h * s * dh);
    dq.par_chunks_mut(s * dh)
        .zip(dk.par_chunks_mut(s * dh))
        .zip(dv.par_chunks_mut(s * dh))
        .enumerate()
        .for_each(|(bh, ((dqc, dkc), dvc))| {
            let base = bh * s * dh;
            let qd = &q.data()[base..base + s * dh];
            let kd = &k.data()[base..base + s * dh];
            let vd = &v.data()[base..base + s * dh];
            let dod = &dout.data()[base..base + s * dh];
            let pd = &probs.data()[bh * s * s..(bh + 1) * s * s];
            let mut dp = vec![0.0f32; s];
            for i in 0..s {
                let doi = &dod[i * dh..(i + 1) * dh];
                let prow = &pd[i * s..(i + 1) * s];
                // dp_j = do_i · v_j; row-sum for the softmax pullback
                let mut psum = 0.0f32;
                for (j, dpj) in dp.iter_mut().enumerate().take(i + 1) {
                    let vj = &vd[j * dh..(j + 1) * dh];
                    *dpj = doi.iter().zip(vj).map(|(&a, &c)| a * c).sum();
                    psum += *dpj * prow[j];
                }
                let dqrow = &mut dqc[i * dh..(i + 1) * dh];
                for j in 0..=i {
                    let p = prow[j];
                    // dv_j += p * do_i
                    let dvrow = &mut dvc[j * dh..(j + 1) * dh];
                    for (o, &g) in dvrow.iter_mut().zip(doi) {
                        *o += p * g;
                    }
                    let ds = p * (dp[j] - psum) * scale;
                    if ds == 0.0 {
                        continue;
                    }
                    let kj = &kd[j * dh..(j + 1) * dh];
                    for (o, &kv) in dqrow.iter_mut().zip(kj) {
                        *o += ds * kv;
                    }
                    let qi = &qd[i * dh..(i + 1) * dh];
                    let dkrow = &mut dkc[j * dh..(j + 1) * dh];
                    for (o, &qv) in dkrow.iter_mut().zip(qi) {
                        *o += ds * qv;
                    }
                }
            }
        });
    let shape = [b, h, s, dh];
    (
        Tensor::new(&shape, dq),
        Tensor::new(&shape, dk),
        Tensor::new(&shape, dv),
    )
}

fn dims4(t: &Tensor) -> (usize, usize, usize, usize) {
    let s = t.shape();
    assert_eq!(s.len(), 4, "expected (B,H,S,dh), got {s:?}");
    (s[0], s[1], s[2], s[3])
}

// ---------------------------------------------------------------------------
// Embedding.
// ---------------------------------------------------------------------------

/// E[tokens] + P[:s] broadcast over the batch -> (B*S, d).  Token ids are
/// clamped to the vocabulary like jax's default clipping gather.
pub fn embed_fwd(tokens: &[i32], b: usize, s: usize, emb: &Tensor, pos: &Tensor) -> Tensor {
    let d = emb.cols();
    let vocab = emb.rows();
    assert_eq!(tokens.len(), b * s);
    let mut out = pool::zeroed(b * s * d);
    out.par_chunks_mut(d).enumerate().for_each(|(bs, row)| {
        let si = bs % s;
        let tok = (tokens[bs].max(0) as usize).min(vocab - 1);
        let erow = &emb.data()[tok * d..(tok + 1) * d];
        let prow = &pos.data()[si * d..(si + 1) * d];
        for j in 0..d {
            row[j] = erow[j] + prow[j];
        }
    });
    Tensor::new(&[b * s, d], out)
}

/// Scatter-add gradient into the token embedding table.
pub fn embed_tokens_bwd(tokens: &[i32], dx: &Tensor, vocab: usize) -> Tensor {
    let d = dx.cols();
    let mut out = pool::zeroed(vocab * d);
    for (bs, &t) in tokens.iter().enumerate() {
        let tok = (t.max(0) as usize).min(vocab - 1);
        let src = &dx.data()[bs * d..(bs + 1) * d];
        let dst = &mut out[tok * d..(tok + 1) * d];
        for (o, &g) in dst.iter_mut().zip(src) {
            *o += g;
        }
    }
    Tensor::new(&[vocab, d], out)
}

/// Positional gradient: sum over the batch dim -> (S, d).
pub fn embed_pos_bwd(dx: &Tensor, b: usize, s: usize) -> Tensor {
    let d = dx.cols();
    let mut out = vec![0.0f64; s * d];
    for bi in 0..b {
        for si in 0..s {
            let src = &dx.data()[(bi * s + si) * d..(bi * s + si + 1) * d];
            let dst = &mut out[si * d..(si + 1) * d];
            for (o, &g) in dst.iter_mut().zip(src) {
                *o += g as f64;
            }
        }
    }
    Tensor::new(&[s, d], out.into_iter().map(|x| x as f32).collect())
}

// ---------------------------------------------------------------------------
// Cross-entropy heads.
// ---------------------------------------------------------------------------

/// Exact next-token NLL sums: (loss_sum, token_count) over (B, S) tokens and
/// (B*S, V) logits — position S-1 of every sequence predicts nothing.
pub fn ce_sums(logits: &Tensor, tokens: &[i32], b: usize, s: usize) -> (f64, f64) {
    let v = logits.cols();
    let ld = logits.data();
    // position-indexed partials + serial reduction: a rayon `sum()` combines
    // in steal order, so the float result would vary with pool size — the
    // loss must be bitwise-stable under any `--threads`/`--jobs` split
    let partials: Vec<f64> = (0..b * s)
        .into_par_iter()
        .map(|bs| {
            let si = bs % s;
            if si + 1 >= s {
                return 0.0f64;
            }
            let row = &ld[bs * v..(bs + 1) * v];
            let tgt = (tokens[bs + 1].max(0) as usize).min(v - 1);
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
            (lse - row[tgt]) as f64
        })
        .collect();
    (partials.iter().sum(), (b * (s - 1)) as f64)
}

/// Mean next-token NLL and its logits gradient (the train-step head).
pub fn ce_grad(logits: &Tensor, tokens: &[i32], b: usize, s: usize) -> (f32, Tensor) {
    let v = logits.cols();
    let count = (b * (s - 1)) as f32;
    let ld = logits.data();
    let mut dl = pool::zeroed(b * s * v);
    // indexed partials, serial sum: keeps the reported loss bitwise-stable
    // across kernel-pool sizes (grad rows are per-chunk writes, already so)
    let partials: Vec<f64> = dl
        .par_chunks_mut(v)
        .enumerate()
        .map(|(bs, drow)| {
            let si = bs % s;
            if si + 1 >= s {
                return 0.0f64; // last position: no target, zero grad
            }
            let row = &ld[bs * v..(bs + 1) * v];
            let tgt = (tokens[bs + 1].max(0) as usize).min(v - 1);
            let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut denom = 0.0f32;
            for (o, &x) in drow.iter_mut().zip(row) {
                *o = (x - mx).exp();
                denom += *o;
            }
            for o in drow.iter_mut() {
                *o /= denom * count;
            }
            drow[tgt] -= 1.0 / count;
            ((denom.ln() + mx) - row[tgt]) as f64
        })
        .collect();
    let loss_sum: f64 = partials.iter().sum();
    (
        (loss_sum / count as f64) as f32,
        Tensor::new(&[b * s, v], dl),
    )
}

/// Per-sequence sum log-prob of tmask-marked tokens (EleutherAI-style
/// likelihood ranking).  Returns (scores, counts), each length B.
pub fn sequence_scores(
    logits: &Tensor,
    tokens: &[i32],
    tmask: &Tensor,
    b: usize,
    s: usize,
) -> (Vec<f32>, Vec<f32>) {
    let v = logits.cols();
    let ld = logits.data();
    let td = tmask.data();
    let pairs: Vec<(f32, f32)> = (0..b)
        .into_par_iter()
        .map(|bi| {
            let mut score = 0.0f64;
            let mut cnt = 0.0f32;
            for si in 0..s - 1 {
                let tm = td[bi * s + si + 1];
                if tm == 0.0 {
                    continue;
                }
                let bs = bi * s + si;
                let row = &ld[bs * v..(bs + 1) * v];
                let tgt = (tokens[bs + 1].max(0) as usize).min(v - 1);
                let mx = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
                score += ((row[tgt] - lse) * tm) as f64;
                cnt += tm;
            }
            (score as f32, cnt)
        })
        .collect();
    (
        pairs.iter().map(|p| p.0).collect(),
        pairs.iter().map(|p| p.1).collect(),
    )
}

/// Shared VJP of the gated low-rank adapter path: given dZ = dYᵀX and a gate
/// (MaskLoRA: the mask with `s` = lora_scale; ScaleLoRA: W⊙M with `s` = 1),
/// G = s·(dZ ⊙ gate), dA = Bᵀ G, dB = G Aᵀ.  Used by both the full-model
/// backward pass and the per-shape reconstruction steps.
pub fn adapter_vjp(
    dz: &Tensor,
    gate: &Tensor,
    a: &Tensor,
    bmat: &Tensor,
    s: f32,
) -> (Tensor, Tensor) {
    let g = dz.hadamard(gate).scale(s);
    let da = linalg::matmul_tn(bmat, &g);
    let db = linalg::matmul_nt(&g, a);
    (da, db)
}

// ---------------------------------------------------------------------------
// AdamW (decoupled weight decay; wd = 0 in every lowered graph).
// ---------------------------------------------------------------------------

/// One AdamW step; `step` is 1-based.  Returns (p', m', v').
pub fn adamw(
    p: &Tensor,
    g: &Tensor,
    m: &Tensor,
    v: &Tensor,
    step: f32,
    lr: f32,
) -> (Tensor, Tensor, Tensor) {
    crate::count!("ops.adamw");
    assert_eq!(p.shape(), g.shape());
    let bc1 = 1.0 - ADAM_BETA1.powf(step);
    let bc2 = 1.0 - ADAM_BETA2.powf(step);
    let n = p.numel();
    let mut p2 = pool::zeroed(n);
    let mut m2 = pool::zeroed(n);
    let mut v2 = pool::zeroed(n);
    let (pd, gd, md, vd) = (p.data(), g.data(), m.data(), v.data());
    p2.par_iter_mut()
        .zip(m2.par_iter_mut())
        .zip(v2.par_iter_mut())
        .enumerate()
        .for_each(|(i, ((po, mo), vo))| {
            let gi = gd[i];
            let mn = ADAM_BETA1 * md[i] + (1.0 - ADAM_BETA1) * gi;
            let vn = ADAM_BETA2 * vd[i] + (1.0 - ADAM_BETA2) * gi * gi;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            *po = pd[i] - lr * (mhat / (vhat.sqrt() + ADAM_EPS));
            *mo = mn;
            *vo = vn;
        });
    (
        Tensor::new(p.shape(), p2),
        Tensor::new(p.shape(), m2),
        Tensor::new(p.shape(), v2),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;
    use crate::util::rng::Rng;

    #[test]
    fn gelu_matches_reference_points() {
        // reference values from jax.nn.gelu (approximate=True)
        let x = Tensor::new(&[4], vec![-2.0, -0.5, 0.0, 1.5]);
        let y = gelu(&x);
        let expect = [-0.045402, -0.154286, 0.0, 1.399572];
        for (a, e) in y.data().iter().zip(expect) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
    }

    #[test]
    fn gelu_vjp_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[64], 1.5, &mut rng);
        let dy = Tensor::ones(&[64]);
        let g = gelu_vjp(&x, &dy);
        let eps = 1e-3;
        for i in 0..64 {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (gelu(&xp).data()[i] - gelu(&xm).data()[i]) / (2.0 * eps);
            assert!((fd - g.data()[i]).abs() < 1e-2, "i={i}: {fd} vs {}", g.data()[i]);
        }
    }

    #[test]
    fn layernorm_normalises_and_roundtrips_grads() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[6, 16], 2.0, &mut rng);
        let scale = Tensor::ones(&[16]);
        let bias = Tensor::zeros(&[16]);
        let (y, cache) = layernorm_fwd(&x, &scale, &bias);
        for i in 0..6 {
            let row = &y.data()[i * 16..(i + 1) * 16];
            let mu: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / 16.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
        // dx orthogonal to constants: a constant shift of x leaves y unchanged
        let dy = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let (dx, pg) = layernorm_bwd(&cache, &scale, &dy, true);
        for i in 0..6 {
            let rsum: f32 = dx.data()[i * 16..(i + 1) * 16].iter().sum();
            assert!(rsum.abs() < 1e-4, "row {i}: {rsum}");
        }
        // dbias is the column sum of dy
        let (_, db) = pg.unwrap();
        assert!(db.allclose(&col_sums(&dy), 1e-5, 1e-5));
        // frozen-norm path skips the reductions but returns the same dx
        let (dx2, none) = layernorm_bwd(&cache, &scale, &dy, false);
        assert!(none.is_none());
        assert_eq!(dx2, dx);
    }

    #[test]
    fn rmsnorm_fwd_bwd_finite_difference() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let scale = Tensor::randn(&[8], 0.5, &mut rng).map(|v| v + 1.0);
        let (_, cache) = rmsnorm_fwd(&x, &scale);
        let dy = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let (dx, _) = rmsnorm_bwd(&cache, &scale, &dy, true);
        let f = |xt: &Tensor| -> f32 {
            let (y, _) = rmsnorm_fwd(xt, &scale);
            y.data().iter().zip(dy.data()).map(|(&a, &b)| a * b).sum()
        };
        let eps = 1e-3;
        for i in [0usize, 5, 13, 23] {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!((fd - dx.data()[i]).abs() < 2e-2, "i={i}: {fd} vs {}", dx.data()[i]);
        }
    }

    #[test]
    fn heads_split_merge_roundtrip() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[2 * 5, 12], 1.0, &mut rng);
        let h = split_heads(&x, 2, 5, 3, 4);
        assert_eq!(h.shape(), &[2, 3, 5, 4]);
        let back = merge_heads(&h, 2, 5, 3, 4);
        assert_eq!(back, x);
    }

    #[test]
    fn attention_is_causal_and_rows_normalise() {
        let mut rng = Rng::new(5);
        let q = Tensor::randn(&[1, 2, 6, 4], 1.0, &mut rng);
        let k = Tensor::randn(&[1, 2, 6, 4], 1.0, &mut rng);
        let v = Tensor::randn(&[1, 2, 6, 4], 1.0, &mut rng);
        let (_, probs) = attention_fwd(&q, &k, &v);
        for h in 0..2 {
            for i in 0..6 {
                let row = &probs.data()[(h * 6 + i) * 6..(h * 6 + i + 1) * 6];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5);
                for (j, &p) in row.iter().enumerate() {
                    if j > i {
                        assert_eq!(p, 0.0, "future leak at ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn attention_bwd_finite_difference() {
        let mut rng = Rng::new(6);
        let q = Tensor::randn(&[1, 1, 5, 3], 0.7, &mut rng);
        let k = Tensor::randn(&[1, 1, 5, 3], 0.7, &mut rng);
        let v = Tensor::randn(&[1, 1, 5, 3], 0.7, &mut rng);
        let dy = Tensor::randn(&[1, 1, 5, 3], 1.0, &mut rng);
        let (_, probs) = attention_fwd(&q, &k, &v);
        let (dq, dk, dv) = attention_bwd(&q, &k, &v, &probs, &dy);
        let f = |qt: &Tensor, kt: &Tensor, vt: &Tensor| -> f32 {
            let (o, _) = attention_fwd(qt, kt, vt);
            o.data().iter().zip(dy.data()).map(|(&a, &b)| a * b).sum()
        };
        let eps = 1e-3;
        for i in [0usize, 4, 9, 14] {
            for (t, g) in [(&q, &dq), (&k, &dk), (&v, &dv)] {
                let mut tp = (*t).clone();
                tp.data_mut()[i] += eps;
                let mut tm = (*t).clone();
                tm.data_mut()[i] -= eps;
                let fd = if std::ptr::eq(t, &q) {
                    (f(&tp, &k, &v) - f(&tm, &k, &v)) / (2.0 * eps)
                } else if std::ptr::eq(t, &k) {
                    (f(&q, &tp, &v) - f(&q, &tm, &v)) / (2.0 * eps)
                } else {
                    (f(&q, &k, &tp) - f(&q, &k, &tm)) / (2.0 * eps)
                };
                assert!((fd - g.data()[i]).abs() < 2e-2, "i={i}: {fd} vs {}", g.data()[i]);
            }
        }
    }

    #[test]
    fn ce_uniform_logits_give_log_v() {
        let (b, s, v) = (2usize, 4usize, 10usize);
        let logits = Tensor::zeros(&[b * s, v]);
        let tokens = vec![3i32; b * s];
        let (sum, count) = ce_sums(&logits, &tokens, b, s);
        assert_eq!(count, (b * (s - 1)) as f64);
        assert!((sum / count - (v as f64).ln()).abs() < 1e-5);
        let (mean, dl) = ce_grad(&logits, &tokens, b, s);
        assert!((mean as f64 - (v as f64).ln()).abs() < 1e-5);
        // grad sums to zero per scored row; zero at final positions
        for bs in 0..b * s {
            let row = &dl.data()[bs * v..(bs + 1) * v];
            let rs: f32 = row.iter().sum();
            if bs % s == s - 1 {
                assert!(row.iter().all(|&x| x == 0.0));
            } else {
                assert!(rs.abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ce_grad_matches_finite_difference() {
        let mut rng = Rng::new(7);
        let (b, s, v) = (2usize, 3usize, 6usize);
        let logits = Tensor::randn(&[b * s, v], 1.0, &mut rng);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(v as u64) as i32).collect();
        let (_, dl) = ce_grad(&logits, &tokens, b, s);
        let eps = 1e-2;
        for i in [0usize, 7, 20, 35] {
            let mut lp = logits.clone();
            lp.data_mut()[i] += eps;
            let mut lm = logits.clone();
            lm.data_mut()[i] -= eps;
            let (sp, c) = ce_sums(&lp, &tokens, b, s);
            let (sm, _) = ce_sums(&lm, &tokens, b, s);
            let fd = ((sp - sm) / (2.0 * eps as f64) / c) as f32;
            assert!((fd - dl.data()[i]).abs() < 1e-3, "i={i}: {fd} vs {}", dl.data()[i]);
        }
    }

    #[test]
    fn sequence_scores_count_masked_positions() {
        let (b, s, v) = (2usize, 4usize, 8usize);
        let logits = Tensor::zeros(&[b * s, v]);
        let tokens = vec![1i32; b * s];
        // mask scores positions 1..3 of sequence 0, nothing of sequence 1
        let mut tm = vec![0.0f32; b * s];
        tm[1] = 1.0;
        tm[2] = 1.0;
        let (scores, counts) = sequence_scores(&logits, &tokens, &Tensor::new(&[b, s], tm), b, s);
        assert_eq!(counts, vec![2.0, 0.0]);
        assert!((scores[0] + 2.0 * (v as f32).ln()).abs() < 1e-4);
        assert_eq!(scores[1], 0.0);
    }

    #[test]
    fn adamw_first_step_is_signed_lr() {
        // with zero state and step 1: mhat = g, vhat = g² -> update ≈ lr·sign(g)
        let p = Tensor::new(&[3], vec![1.0, 2.0, -3.0]);
        let g = Tensor::new(&[3], vec![0.5, -0.25, 4.0]);
        let z = Tensor::zeros(&[3]);
        let (p2, m2, v2) = adamw(&p, &g, &z, &z, 1.0, 0.1);
        for i in 0..3 {
            let expect = p.data()[i] - 0.1 * g.data()[i].signum();
            assert!((p2.data()[i] - expect).abs() < 1e-4);
            assert!((m2.data()[i] - 0.1 * g.data()[i]).abs() < 1e-6);
            assert!((v2.data()[i] - 0.001 * g.data()[i] * g.data()[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn embed_and_grads_are_consistent() {
        let mut rng = Rng::new(8);
        let (b, s, v, d) = (2usize, 3usize, 5usize, 4usize);
        let emb = Tensor::randn(&[v, d], 1.0, &mut rng);
        let pos = Tensor::randn(&[s, d], 1.0, &mut rng);
        let tokens = vec![0i32, 1, 2, 2, 4, 0];
        let x = embed_fwd(&tokens, b, s, &emb, &pos);
        assert_eq!(x.shape(), &[b * s, d]);
        // row (1, 2) = E[0] + P[2]
        for j in 0..d {
            let got = x.data()[5 * d + j];
            assert!((got - (emb.data()[j] + pos.data()[2 * d + j])).abs() < 1e-6);
        }
        let dx = Tensor::ones(&[b * s, d]);
        let de = embed_tokens_bwd(&tokens, &dx, v);
        // token 2 appears twice
        assert!((de.data()[2 * d] - 2.0).abs() < 1e-6);
        // token 3 never
        assert_eq!(de.data()[3 * d], 0.0);
        let dp = embed_pos_bwd(&dx, b, s);
        assert!(dp.data().iter().all(|&g| (g - b as f32).abs() < 1e-6));
    }

    #[test]
    fn col_sums_matches_matmul() {
        let mut rng = Rng::new(9);
        let dy = Tensor::randn(&[13, 7], 1.0, &mut rng);
        let ones = Tensor::ones(&[13, 1]);
        let via_mm = linalg::matmul_tn(&dy, &ones); // (7,1)ᵀ... (7,1)
        let cs = col_sums(&dy);
        for j in 0..7 {
            assert!((cs.data()[j] - via_mm.data()[j]).abs() < 1e-4);
        }
    }
}
